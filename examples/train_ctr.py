"""End-to-end driver: train a ~100M-parameter DCNv2 on synthetic Criteo for
a few hundred steps with checkpoint/restart fault tolerance, then evaluate
AUC/LogLoss served through the DPIFrame executor.

The full Criteo schema at d=16 gives ≈107M embedding parameters — the
"~100M model for a few hundred steps" deliverable. Interrupt it at any
point and re-run: it resumes from the newest intact checkpoint.

Run:  PYTHONPATH=src python examples/train_ctr.py [--steps 300]
"""

import argparse

import numpy as np
import jax

from repro.configs import ctr_spec
from repro.core import DualParallelExecutor
from repro.data.synthetic import CRITEO, synthetic_batch
from repro.models.ctr import DCNv2
from repro.training import (AdamWConfig, TrainLoopConfig, adamw_init,
                            adamw_update, logloss, roc_auc, run_train_loop)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=1024)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ctr_ckpt")
    args = ap.parse_args()

    schema = CRITEO          # full heavy-tail schema: ~6.7M rows
    spec = ctr_spec("dcnv2", "criteo", embed_dim=16, hidden=256)
    model = DCNv2(spec)
    params = model.init(jax.random.PRNGKey(0))
    n = model.n_params(params)
    print(f"model: dcnv2/criteo  params = {n/1e6:.1f}M")

    opt = AdamWConfig(lr=1e-3)
    state = adamw_init(params, opt)

    @jax.jit
    def step_fn(state, batch):
        loss, grads = jax.value_and_grad(model.loss)(state.params, batch)
        state, metrics = adamw_update(state, grads, opt)
        return state, {"loss": loss, **metrics}

    loop_cfg = TrainLoopConfig(total_steps=args.steps, ckpt_every=100,
                               ckpt_dir=args.ckpt_dir, log_every=25)
    state, hist = run_train_loop(
        step_fn, state,
        batch_fn=lambda s: synthetic_batch(schema, s, args.batch),
        cfg=loop_cfg)

    # evaluation through the DPIFrame dual executor
    ex = DualParallelExecutor(model.build_graph, level="dual")
    serve = ex.build(state.params)
    val = synthetic_batch(schema, 10_000, 8192)
    logits = np.asarray(serve({"ids": val["ids"]})).reshape(-1)
    probs = 1 / (1 + np.exp(-logits))
    labels = np.asarray(val["labels"])
    print(f"val AUC = {roc_auc(labels, probs):.4f}   "
          f"LogLoss = {logloss(labels, probs):.4f}")
    print(f"first-loss {hist[0]['loss']:.4f} -> last-loss "
          f"{hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
