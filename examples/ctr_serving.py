"""Batched CTR serving demo — the paper's deployment scenario.

Trains DCN briefly, then serves 2,000 single-sample requests through the
CTRServingEngine (dynamic batching + DPIFrame dual-parallel executor) and
prints throughput/latency stats next to the naive-executor configuration.

Run:  PYTHONPATH=src python examples/ctr_serving.py
"""

import time

import numpy as np
import jax

from repro.configs import ctr_spec
from repro.data.synthetic import CRITEO, synthetic_batch
from repro.models.ctr import DCN
from repro.serving import CTRServingEngine

MAX_FIELD = 100_000
N_REQUESTS = 2_000
BATCH = 256

schema = CRITEO.scaled(MAX_FIELD)
spec = ctr_spec("dcn", "criteo", embed_dim=16, hidden=256,
                max_field=MAX_FIELD)
model = DCN(spec)
params = model.init(jax.random.PRNGKey(0))

rng = np.random.default_rng(0)
requests = [np.array([rng.integers(0, s) for s in schema.field_sizes],
                     dtype=np.int32) for _ in range(N_REQUESTS)]

for level in ("naive", "dual"):
    eng = CTRServingEngine(model, params, batch_size=BATCH, level=level)
    eng.warmup()
    t0 = time.perf_counter()
    for r in requests:
        eng.submit(r)
    scores = eng.serve_pending()
    dt = time.perf_counter() - t0
    s = eng.stats
    print(f"{level:6s}: {N_REQUESTS/dt:8.0f} req/s   "
          f"p50={s.p50_ms:7.1f}ms p99={s.p99_ms:7.1f}ms   "
          f"batches={s.n_batches} compute={s.compute_ms_total:6.1f}ms")
print("sample scores:", np.round(scores[:5], 4))
