"""Batched CTR serving demo — the paper's deployment scenario.

Trains nothing (random params suffice for throughput numbers); serves 2,000
single-sample requests arriving in mixed-size waves through the
InferenceEngine, comparing the legacy pad-to-256 FixedBatch against
BucketedBatch (one cached InferencePlan per bucket) at the naive and dual
executor levels, and prints throughput/latency plus the engine's plan-cache
and padding-waste counters.

Run:  PYTHONPATH=src python examples/ctr_serving.py
"""

import time

import numpy as np
import jax

from repro.configs import ctr_spec
from repro.data.synthetic import CRITEO
from repro.models.ctr import DCN
from repro.serving import BucketedBatch, FixedBatch, InferenceEngine

MAX_FIELD = 100_000
N_REQUESTS = 2_000
LADDER = (32, 64, 128, 256)

schema = CRITEO.scaled(MAX_FIELD)
spec = ctr_spec("dcn", "criteo", embed_dim=16, hidden=256,
                max_field=MAX_FIELD)
model = DCN(spec)
params = model.init(jax.random.PRNGKey(0))

rng = np.random.default_rng(0)
requests = [np.array([rng.integers(0, s) for s in schema.field_sizes],
                     dtype=np.int32) for _ in range(N_REQUESTS)]
# mixed-size arrival waves: bursts of 256 down to straggler handfuls
waves, i = [], 0
for size in (256, 256, 512, 96, 640, 130, 70, 17, 19, 4):
    waves.append(requests[i:i + size])
    i += size

for level in ("naive", "dual"):
    for policy in (FixedBatch(256), BucketedBatch(LADDER)):
        eng = InferenceEngine(model, params, level=level, policy=policy)
        eng.warmup()
        t0 = time.perf_counter()
        scores = []
        for wave in waves:
            eng.submit_many(wave)
            scores.append(eng.serve_pending())
        scores = np.concatenate(scores)
        dt = time.perf_counter() - t0
        s = eng.stats
        name = type(policy).__name__
        print(f"{level:6s}/{name:13s}: {N_REQUESTS/dt:8.0f} req/s  "
              f"p50={s.p50_ms:6.1f}ms p99={s.p99_ms:6.1f}ms  "
              f"batches={s.n_batches:3d}  plans={len(eng.cached_plans)}  "
              f"pad_waste={s.padding_waste:5.1%}  "
              f"cache h/m={s.cache_hits}/{s.cache_misses}")

print("sample scores:", np.round(scores[:5], 4))
