"""Quickstart: DPIFrame in ~40 lines.

Builds DCNv2 on the (synthetic) Criteo schema, compiles one InferencePlan
per executor level (naive → DPIFrame-C), and shows: identical outputs
(Table-I property), the kernel-count reduction from non-GEMM fusion, and the
breadth-first schedule. ``compile_plan`` is the single compile surface —
the returned plan carries the fused graph, the schedule, and an AOT-compiled
step function.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax

from repro.configs import ctr_spec
from repro.core import compile_plan
from repro.data.synthetic import CRITEO, synthetic_batch
from repro.models.ctr import DCNv2

BATCH = 256

spec = ctr_spec("dcnv2", "criteo", embed_dim=16, hidden=256,
                max_field=50_000)
model = DCNv2(spec)
params = model.init(jax.random.PRNGKey(0))
batch = synthetic_batch(CRITEO.scaled(50_000), step=0, batch=BATCH)

outputs = {}
for level in ("naive", "fused_emb", "fused_all", "dual"):
    plan = compile_plan(model, params, level, BATCH)
    outputs[level] = np.asarray(plan(batch["ids"]))
    st = plan.stats
    print(f"{level:10s} ops {st.n_ops_before:2d} -> {st.n_ops_after:2d}  "
          f"fused_groups={st.n_fused_groups}  policy={st.schedule_policy}  "
          f"compile={plan.compile_ms:6.0f}ms")

print("\nbreadth-first queue:", " | ".join(plan.stats.queue[:6]), "...")
for level, out in outputs.items():
    assert np.allclose(out, outputs["naive"], rtol=1e-5, atol=1e-6), level
print("\nall levels numerically identical — the paper's Table-I property")
