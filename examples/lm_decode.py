"""LM serving demo: prefill + KV-cache decode on reduced assigned archs.

Exercises three architecture families end to end through the generation
driver (dense GQA, RWKV6 constant-state, Zamba2 hybrid).

Run:  PYTHONPATH=src python examples/lm_decode.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.lm import make_lm_model
from repro.serving import generate

for arch in ("llama3-8b", "rwkv6-7b", "zamba2-1.2b"):
    cfg = get_config(arch).reduced(n_layers=4, d_model=128, d_ff=256,
                                   vocab=512)
    model = make_lm_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    t0 = time.perf_counter()
    out = generate(model, params, prompt, max_new=16)
    dt = time.perf_counter() - t0
    assert out.shape == (2, 12 + 16)
    print(f"{arch:12s} ({cfg.family:6s}) generated {out.shape[1]-12} tokens "
          f"in {dt*1e3:6.1f}ms -> {out[0, 12:18].tolist()}...")
print("decode paths OK across families")
