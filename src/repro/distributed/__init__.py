"""Sharding policies (DP/FSDP/TP/EP/SP) for the production mesh."""

from . import sharding

__all__ = ["sharding"]
