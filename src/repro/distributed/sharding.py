"""Per-architecture sharding policies: DP(+pod) × FSDP(data) × TP/EP/SP(model).

Two products:

* ``make_shard_fn(mesh)`` — the activation-constraint callable injected into
  models (logical axis names -> mesh axes via LOGICAL_RULES).
* ``param_specs(family, shapes)`` — a PartitionSpec pytree matching the
  params tree, built from path-pattern rules. The same specs shard the
  optimizer mirror states (ZeRO-style: fp32 m/v live fully sharded).

Policy summary (DESIGN.md §5):
  batch        -> ("pod", "data")         (DP across pods × data axis)
  TP           -> "model" on heads / d_ff / vocab / experts
  FSDP         -> "data" on the non-TP matrix dim of every large weight
  SP           -> "model" on the KV-cache sequence dim for decode cells
                  (flash-decode style distributed attention, GSPMD-lowered)
Uneven shardings (smollm's 15 heads over 16, whisper's 51865 vocab) are
legal under GSPMD — padding is implicit; the dry-run proves they compile.
"""

from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["LOGICAL_RULES", "make_shard_fn", "param_specs", "batch_specs",
           "cache_specs", "to_named", "mesh_batch_axes", "input_shardings"]


def mesh_batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def LOGICAL_RULES(mesh: Mesh) -> dict[str, Any]:
    batch = mesh_batch_axes(mesh)
    b = batch if len(batch) > 1 else (batch[0] if batch else None)
    return {
        "batch": b,
        "seq": None,
        "kv_seq": "model",       # sequence-parallel KV cache (decode)
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "expert_mlp": None,      # model axis is taken by experts in MoE
        "vocab": "model",
        "experts": "model",
    }


def LOGICAL_RULES_FSDP(mesh: Mesh) -> dict[str, Any]:
    """Pure-FSDP policy (H2): batch over (data × model), weights fully
    sharded and gathered per layer, NO tensor parallelism — eliminates the
    per-layer activation all-reduces that dominate the TP policy's
    collective term."""
    batch = tuple(a for a in ("data", "model") if a in mesh.axis_names)
    b = batch if len(batch) > 1 else (batch[0] if batch else None)
    rules = {k: None for k in LOGICAL_RULES(mesh)}
    rules["batch"] = b
    rules["kv_seq"] = None
    return rules


def make_shard_fn(mesh: Mesh, policy: str = "tp_fsdp"):
    rules = (LOGICAL_RULES_FSDP(mesh) if policy == "fsdp"
             else LOGICAL_RULES(mesh))

    def shard(x, logical_axes):
        spec = P(*(rules.get(a) if a is not None else None
                   for a in logical_axes))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))
    return shard


def fsdp_param_specs(specs: Any) -> Any:
    """Rewrite TP×FSDP param specs to pure-FSDP: the TP ('model') dim takes
    the full ('data','model') grid; the old FSDP ('data') dim is freed."""
    def fix(spec):
        out = []
        for dim in spec:
            if dim == "model":
                out.append(("data", "model"))
            elif dim == "data":
                out.append(None)
            else:
                out.append(dim)
        return P(*out)
    return jax.tree.map(fix, specs, is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# parameter partition specs (path-pattern rules per family)
# ---------------------------------------------------------------------------
# Each rule: (regex over "/"-joined tree path, PartitionSpec *without* the
# leading scan dim — a leading None is prepended automatically when the leaf
# has one more dim than the spec).

_DENSE_RULES = [
    (r"embed$", P("model", "data")),
    (r"lm_head$", P("data", "model")),
    (r"attn/w[qkv]$", P("data", "model")),
    (r"attn/wo$", P("model", "data")),
    (r"mlp/w_(gate|up|in)$", P("data", "model")),
    (r"mlp/w_(down|out)$", P("model", "data")),
    (r"mlp/b_in$", P("model")),
    (r"pos_dec$", P(None, None)),
]

_MOE_RULES = [
    (r"moe/router$", P(None, None)),
    (r"moe/w_(gate|up)$", P("model", "data", None)),    # E × D × F
    (r"moe/w_down$", P("model", None, "data")),         # E × F × D
] + _DENSE_RULES

# H3 (llama4-scale): weight-stationary experts — E over model AND the FFN
# dim over data so the 800 GB expert bank never moves; the (much smaller)
# dispatched token buffers replicate over data instead (moe.py).
_MOE_TOKEN_REPLICATE_RULES = [
    (r"moe/router$", P(None, None)),
    (r"moe/w_(gate|up)$", P("model", None, "data")),    # E × D × F/data
    (r"moe/w_down$", P("model", "data", None)),         # E × F/data × D
] + _DENSE_RULES

_RWKV_RULES = [
    (r"embed$", P("model", "data")),
    (r"lm_head$", P("data", "model")),
    (r"w[rkvg]$", P("data", "model")),
    (r"wo$", P("model", "data")),
    (r"wck$", P("data", "model")),
    (r"wcv$", P("model", "data")),
    (r"wcr$", P("data", "model")),
    (r"w_lora_a$", P("data", None)),
    (r"w_lora_b$", P(None, "data")),
    (r"(^|/)u$", P("model", None)),
]

_ZAMBA_RULES = [
    (r"embed$", P("model", "data")),
    (r"lm_head$", P("data", "model")),
    (r"mamba/w_in$", P("data", "model")),
    (r"mamba/w_out$", P("model", "data")),
    (r"mamba/conv_w$", P(None, "model")),
    (r"mamba/ln_y$", P("model")),
    (r"shared/w_in$", P("data", "model")),
    (r"shared/attn/w[qkv]$", P("data", "model")),
    (r"shared/attn/wo$", P("model", "data")),
    (r"shared/mlp/w_(gate|up)$", P("data", "model")),
    (r"shared/mlp/w_down$", P("model", "data")),
]

_ENCDEC_RULES = [
    (r"(xattn|attn)/w[qkv]$", P("data", "model")),
    (r"(xattn|attn)/wo$", P("model", "data")),
] + _DENSE_RULES

_FAMILY_RULES = {
    "dense": _DENSE_RULES,
    "vlm": _DENSE_RULES,
    "moe": _MOE_RULES,
    "ssm": _RWKV_RULES,
    "hybrid": _ZAMBA_RULES,
    "encdec": _ENCDEC_RULES,
}


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(family: str, shapes: Any, cfg: Any = None) -> Any:
    """ShapeDtypeStruct tree -> PartitionSpec tree for the family."""
    rules = _FAMILY_RULES[family]
    if (family == "moe" and cfg is not None
            and getattr(cfg, "moe_token_replicate", False)):
        rules = _MOE_TOKEN_REPLICATE_RULES

    def leaf_spec(path, leaf):
        ps = _path_str(path)
        for pat, spec in rules:
            if re.search(pat, ps):
                if len(spec) == leaf.ndim - 1:
                    return P(None, *spec)          # stacked-scan leading dim
                if len(spec) == leaf.ndim:
                    return spec
                # rank mismatch (e.g. 1-D spec vs scalar) -> replicate
                return P()
        return P()                                  # norms, scalars: replicate

    return jax.tree_util.tree_map_with_path(leaf_spec, shapes)


def ctr_param_specs(shapes: Any) -> Any:
    """CTR models: embedding tables row-sharded over model, dense replicated
    (they are latency-bound, DESIGN §5).

    Training-side twin of the serving path's store-delegated placement
    (``CTRModel.partition_spec``): the store leaf names are the contract —
    ``mega_table`` (DenseStore) and ``backing`` (CachedStore) are the
    vocab-parallel tables; a CachedStore's ``cache``/``slot_of_row`` tiers
    stay replicated (small and latency-critical).
    """
    def leaf_spec(path, leaf):
        ps = _path_str(path)
        if ((ps.endswith("mega_table") or ps.endswith("backing"))
                and leaf.ndim == 2):
            return P("model", None)
        if ps.endswith("cache") or ps.endswith("slot_of_row"):
            return P()
        if leaf.ndim == 2 and leaf.shape[0] * leaf.shape[1] >= 1 << 16:
            return P(None, "model")
        return P()
    return jax.tree_util.tree_map_with_path(leaf_spec, shapes)


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------

def batch_specs(mesh: Mesh, batch_tree: Any) -> Any:
    """Shard the leading (global-batch) dim of every batch leaf (replicate
    everything on a mesh with no batch axis at all)."""
    b = mesh_batch_axes(mesh)
    b = b if len(b) > 1 else (b[0] if b else None)

    def leaf(x):
        return P(*([b] + [None] * (x.ndim - 1)))
    return jax.tree.map(leaf, batch_tree)


def cache_specs(family: str, mesh: Mesh, cache_tree: Any,
                seq_shard: bool = True) -> Any:
    """KV/state cache placement for decode cells.

    Dense/MoE/VLM k,v: (L, B, S, kv, hd) -> batch over data(+pod), seq over
    model (SP flash-decode). SSM states: batch over data, heads over model.
    """
    b = mesh_batch_axes(mesh)
    b = b if len(b) > 1 else b[0]
    sp = "model" if seq_shard else None

    def leaf(path, x):
        ps = _path_str(path)
        if x.ndim == 5 and ("k" in ps or "v" in ps):   # (L, B, S, kv, hd)
            return P(None, b, sp, None, None)
        if ps.endswith("index"):
            return P()
        if x.ndim >= 4:                                 # ssm states etc.
            return P(None, b, "model", *([None] * (x.ndim - 3)))
        if x.ndim >= 2:
            return P(None, b, *([None] * (x.ndim - 2)))
        return P()
    return jax.tree_util.tree_map_with_path(leaf, cache_tree)


def drop_axis(spec_tree: Any, axis: str) -> Any:
    """Remove one mesh axis from every PartitionSpec in the tree."""
    def fix(spec):
        out = []
        for dim in spec:
            if dim == axis:
                out.append(None)
            elif isinstance(dim, tuple):
                kept = tuple(a for a in dim if a != axis)
                out.append(kept if len(kept) > 1 else
                           (kept[0] if kept else None))
            else:
                out.append(dim)
        return P(*out)
    return jax.tree.map(fix, spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def fit_spec(mesh: Mesh, spec: P, shape) -> P:
    """Drop mesh axes from dims they don't divide evenly.

    pjit *argument* shardings must divide exactly (unlike intermediate
    constraints, which GSPMD pads): whisper's 51865 vocab over 16, or a
    batch of 1 on the data axis, must fall back to replication on that dim.
    """
    dims = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for size, axes in zip(shape, dims):
        if axes is None:
            out.append(None)
            continue
        ax_tuple = axes if isinstance(axes, tuple) else (axes,)
        n = 1
        for a in ax_tuple:
            n *= mesh.shape[a]
        out.append(axes if size % n == 0 else None)
    return P(*out)


def fit_spec_tree(mesh: Mesh, specs: Any, shapes: Any) -> Any:
    return jax.tree.map(
        lambda s, x: fit_spec(mesh, s, x.shape), specs, shapes,
        is_leaf=lambda s: isinstance(s, P))


def to_named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))


def input_shardings(mesh: Mesh, shapes: Any) -> Any:
    """NamedShardings for per-call plan inputs (``ids``/``weights``-style
    leaves): leading global-batch dim over the mesh's batch axes
    (``batch_specs``), fitted per leaf (``fit_spec``) so a batch size the
    data axis doesn't divide falls back to replication on that dim instead
    of tripping pjit's argument-divisibility rule."""
    specs = fit_spec_tree(mesh, batch_specs(mesh, shapes), shapes)
    return to_named(mesh, specs)
