"""HostBackedStore — out-of-HBM embedding tier with an async prefetch path.

``CachedStore`` caches hot rows but still keeps the *full* backing table in
device memory, so the largest servable vocabulary is bounded by one chip's
HBM. This store removes that ceiling — the HugeCTR hierarchical parameter
server (arXiv:2210.08804 / 2210.08803) brought to the DPIFrame stack:

  device   ``cache``       (C, d)   hot-row copies (admission-managed)
           ``slot_of_row`` (rows,)  int32 cache map, -1 = uncached
           ``staging``     (S, d)   per-batch copies of this batch's misses
           ``staging_slot_of_row`` (rows,) int32 staging map, -1 = unstaged
  host     backing table   (rows, d) numpy array — **never uploaded whole**
  disk     optional third tier: ``backing_path=`` memory-maps the backing
           from a file (``np.memmap``), so the table need not fit host RAM
           either.

A lookup is one **three-way select** inside the scalar-prefetch gather
(``kernels.mtl_gather_three_level`` on TPU, jnp twin on CPU): cache hit →
cache row, staged miss → staging row, neither → zero-guard. Correctness
therefore rests on the serve path resolving every miss *before* the
lookup: ``stage(params, ids)`` gathers the batch's uncached rows from the
host backing into the staging buffer (most already there thanks to the
:class:`~repro.embedding.prefetch.PrefetchPipeline`'s async hints) and
publishes fresh ``staging``/``staging_slot_of_row`` tensors through the
same double-buffered swap a refresh uses — all four device tensors are
``runtime_keys``, so compiled plans survive every batch and every refresh
with zero recompiles. Bit-exactness with ``DenseStore`` is the hard
contract: staged and cached rows are verbatim copies of backing rows.

When a single batch's distinct miss set exceeds ``S``, ``stage`` raises
``StagingOverflowError`` and the caller serves the batch in chunks
(:meth:`split_for_staging`) — a synchronous host gather in waves, slower
but never wrong.
"""

from __future__ import annotations

import dataclasses
import os

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import quant
from repro.kernels import ops as kops

from .prefetch import PrefetchPipeline, StagingOverflowError
from .spec import FusedEmbeddingSpec
from .store import EmbeddingStore, validate_deltas

__all__ = ["HostBackedStore"]


class HostBackedStore(EmbeddingStore):
    """Hot-row device cache + staging buffer over a host-resident backing.

    Args:
        spec: the fused embedding schema.
        capacity: device cache rows ``C`` (clamped to ``spec.rows``).
        staging_capacity: staging slots ``S``; must cover one sample's
            worst-case miss set (``k * multi_hot``) so chunked serving can
            always make progress. Default ``max(4 * k * multi_hot, 256)``
            (clamped to ``spec.rows``).
        backing_path: optional file for the third tier — the backing table
            is a ``np.memmap`` of this file instead of a RAM array. Create
            via :meth:`init`/:meth:`adopt` (writes the table), reopen an
            existing file with :meth:`open`.
        row_dtype: ``"int8"`` stores all three tiers quantized (symmetric
            absmax, one fp32 scale per row — ``repro.quant``): the host
            backing is int8 + an ``(rows, 1)`` scale column (the mmap tier
            writes the scales to a ``backing_path + ".scale"`` sidecar),
            the staging pipeline moves ``d + 4`` bytes per resolved row
            instead of ``4·d``, and the gather dequantizes in-kernel
            (``mtl_gather_three_level_q8``). Default ``None`` keeps the
            bit-exact full-precision tiers.

    The param subtree holds **only the four device tensors**; the backing
    lives on the store object itself (``host_view()``), which is exactly
    what keeps device-resident embedding bytes at ``(C + S) * d`` plus two
    int32 maps while ``rows`` grows arbitrarily. Consequences: ``lookup``
    requires prior staging, and ``dense_view`` (the serial/naive-level and
    shard_map paths, which want the whole table on device) raises.
    """

    refreshable = True
    needs_staging = True
    runtime_keys = ("cache", "slot_of_row", "staging", "staging_slot_of_row")

    def __init__(self, spec: FusedEmbeddingSpec, capacity: int,
                 staging_capacity: int | None = None,
                 backing_path: str | os.PathLike | None = None,
                 row_dtype: str | None = None):
        if row_dtype is not None:
            spec = dataclasses.replace(spec, row_dtype=row_dtype)
        super().__init__(spec)
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(min(capacity, spec.rows))
        per_sample = spec.k * spec.multi_hot
        if staging_capacity is None:
            staging_capacity = max(4 * per_sample, 256)
        if staging_capacity < per_sample:
            raise ValueError(
                f"staging_capacity {staging_capacity} < one sample's "
                f"worst-case miss set k*multi_hot = {per_sample}; chunked "
                "serving could never make progress")
        self.staging_capacity = int(min(staging_capacity, spec.rows))
        self.backing_path = os.fspath(backing_path) if backing_path else None
        self._backing: np.ndarray | None = None
        self._backing_scale: np.ndarray | None = None
        if self.quantized:
            self.runtime_keys = ("cache", "cache_scale", "slot_of_row",
                                 "staging", "staging_scale",
                                 "staging_slot_of_row")
        self._counts = np.zeros(spec.rows, dtype=np.int64)
        self._slot_of_row = self._seed_map()
        self.pipeline = PrefetchPipeline(self, self.staging_capacity)
        # cached device staging tensors, reused while the staging area is
        # unchanged (an all-hit batch re-publishes without moving a byte)
        self._staged_dev: tuple[int, dict] | None = None
        self._staging_sharding = None   # set via bind_mesh

    def _seed_map(self) -> np.ndarray:
        m = np.full(self.spec.rows, -1, dtype=np.int32)
        m[:self.capacity] = np.arange(self.capacity, dtype=np.int32)
        return m

    # -- host backing --------------------------------------------------------
    def host_view(self) -> np.ndarray:
        """The (rows, d) backing table — host memory (or disk via mmap).
        *Wire* format: int8 for quantized stores (see
        :meth:`host_scale_view`), ``spec.dtype`` otherwise."""
        if self._backing is None:
            raise RuntimeError("no backing attached yet — call init/adopt "
                               "(or HostBackedStore.open for an existing "
                               "backing_path)")
        return self._backing

    def host_scale_view(self) -> np.ndarray:
        """The (rows, 1) fp32 per-row scale column of a quantized backing
        (the prefetch pipeline stages it alongside each int8 row)."""
        if self._backing_scale is None:
            raise RuntimeError("no quantized backing attached — scales "
                               "exist only for row_dtype='int8' stores "
                               "with a backing")
        return self._backing_scale

    def cache_map_view(self) -> np.ndarray:
        """Host mirror of ``slot_of_row`` (the prefetch worker reads it)."""
        return self._slot_of_row

    @property
    def _scale_path(self) -> str | None:
        """Sidecar file of the mmap tier's per-row scales."""
        return self.backing_path + ".scale" if self.backing_path else None

    def _set_backing(self, table: np.ndarray) -> None:
        table = np.ascontiguousarray(
            np.asarray(table, dtype=np.dtype(self.spec.dtype)))
        if table.shape != (self.spec.rows, self.spec.dim):
            raise ValueError(f"backing shape {table.shape} != "
                             f"{(self.spec.rows, self.spec.dim)}")
        scale = None
        if self.quantized:
            # quantize once; every tier (cache/staging) copies these rows
            table, scale = quant.quantize_rows(table)
            self.stats.quant_rows += int(table.shape[0])
        if self.backing_path is not None:
            mm = np.memmap(self.backing_path, dtype=table.dtype, mode="w+",
                           shape=table.shape)
            mm[:] = table
            mm.flush()
            self._backing = mm
            if scale is not None:
                sm = np.memmap(self._scale_path, dtype=np.float32,
                               mode="w+", shape=scale.shape)
                sm[:] = scale
                sm.flush()
                self._backing_scale = sm
        else:
            self._backing = table
            self._backing_scale = scale

    @classmethod
    def open(cls, spec: FusedEmbeddingSpec, capacity: int,
             backing_path: str | os.PathLike,
             staging_capacity: int | None = None,
             row_dtype: str | None = None,
             mode: str = "r") -> "HostBackedStore":
        """Attach an existing on-disk backing (written by a prior
        :meth:`init`/:meth:`adopt` with the same spec) without copying it
        into RAM — the disk third tier's load path. ``row_dtype`` must
        match what the file was written with (int8 backings carry their
        scales in the ``backing_path + ".scale"`` sidecar). ``mode="r"``
        (default) maps the file read-only — :meth:`apply_deltas` then
        rejects pushes; reopen with ``mode="r+"`` to serve a backing that
        also accepts online trainer deltas."""
        if mode not in ("r", "r+"):
            raise ValueError(f"mode must be 'r' or 'r+', got {mode!r}")
        store = cls(spec, capacity, staging_capacity=staging_capacity,
                    backing_path=backing_path, row_dtype=row_dtype)
        wire = np.int8 if store.quantized else np.dtype(spec.dtype)
        store._backing = np.memmap(store.backing_path, dtype=wire,
                                   mode=mode, shape=(spec.rows, spec.dim))
        if store.quantized:
            store._backing_scale = np.memmap(
                store._scale_path, dtype=np.float32, mode=mode,
                shape=(spec.rows, 1))
        return store

    # -- params --------------------------------------------------------------
    def init(self, key: jax.Array) -> dict:
        # same canonical table init as every store (value-identical with a
        # DenseStore built from the same key), then moved off device
        table = np.asarray(self.init_dense_table(key))
        self._set_backing(table)
        return self.device_params()

    def from_dense(self, dense_params: dict) -> dict:
        return self.adopt(dense_params)

    def adopt(self, params: dict) -> dict:
        leaf = params.get("mega_table", params.get("backing"))
        if leaf is None:
            raise ValueError("adopt needs a dense ('mega_table') or cached "
                             "('backing') subtree — a host-backed subtree "
                             "has no table to adopt; use open()")
        leaf = np.asarray(leaf)
        if leaf.dtype == np.int8 and "backing_scale" in params:
            # an already-quantized cached subtree: reconstitute fp rows so
            # _set_backing canonicalizes (and re-quantizes on-grid values)
            leaf = quant.dequantize_rows(leaf,
                                         np.asarray(params["backing_scale"]))
        self._set_backing(leaf)
        return self.device_params()

    def device_params(self) -> dict:
        """Build the device subtree (four tensors, six when quantized)
        from the current backing + index maps (cache rows are verbatim
        backing copies — of the int8 grid, for quantized stores)."""
        backing = self.host_view()
        hot = np.flatnonzero(self._slot_of_row >= 0)
        cached_rows = hot[np.argsort(self._slot_of_row[hot])]
        if cached_rows.size != self.capacity:
            raise ValueError(f"index map holds {cached_rows.size} slots, "
                             f"capacity is {self.capacity}")
        out = {"cache": jnp.asarray(backing[cached_rows]),
               "slot_of_row": jnp.asarray(self._slot_of_row),
               **self._staging_leaves()}
        if self.quantized:
            out["cache_scale"] = jnp.asarray(
                self.host_scale_view()[cached_rows])
        return out

    def bind_mesh(self, mesh, model_axis: str | None = "model") -> None:
        """Make per-batch staging uploads land replicated on ``mesh`` (the
        engine calls this once at construction). Refresh-built tensors go
        through :meth:`place` as for any store; this covers the stage-time
        publishes, so the params an engine holds never mix single-device
        staging tensors into an otherwise mesh-placed tree."""
        if mesh is None:
            self._staging_sharding = None
        else:
            from jax.sharding import NamedSharding
            self._staging_sharding = NamedSharding(mesh, P())
        self._staged_dev = None

    def _staging_leaves(self) -> dict:
        """Device staging leaves for the pipeline's current state (incl.
        the scale sidecar when quantized), reusing the previous upload
        when the staging area hasn't changed."""
        buf, sbuf, smap, version = self.pipeline.snapshot()
        if self._staged_dev is not None and self._staged_dev[0] == version:
            return self._staged_dev[1]
        if self._staging_sharding is not None:
            put = lambda a: jax.device_put(a, self._staging_sharding)
        else:
            put = jnp.asarray
        leaves = {"staging": put(buf), "staging_slot_of_row": put(smap)}
        if sbuf is not None:
            leaves["staging_scale"] = put(sbuf)
        self._staged_dev = (version, leaves)
        return leaves

    def partition_spec(self, model_axis: str | None = "model") -> dict:
        """Every device leaf is small and latency-critical — replicated
        (scales placed like ``slot_of_row``). The backing never appears
        here: it is host state, not a param."""
        return {k: P() for k in self.runtime_keys}

    def dense_view(self, params: dict) -> jax.Array:
        raise NotImplementedError(
            "HostBackedStore keeps the backing table host-side; there is "
            "no device-resident dense view (that ceiling is the point). "
            "Use host_view() for host-side access, or a DenseStore/"
            "CachedStore for paths that need the whole table on device "
            "(serial baselines, the 'naive' level, apply_sharded).")

    # -- staging (the per-batch miss pipeline) -------------------------------
    def _global_rows(self, ids, mask=None) -> np.ndarray:
        """Local (…, k[, h]) ids -> clipped global rows, masked slots
        dropped (their lookup is zero-guarded, nothing to stage)."""
        ids = np.asarray(ids, dtype=np.int64)
        if ids.ndim == 1:
            ids = ids[None, :]
        offs = self.spec.offsets
        rows = ids + (offs[None, :] if ids.ndim == 2 else offs[None, :, None])
        if mask is not None:
            rows = rows[np.asarray(mask).astype(bool)]
        return np.clip(rows.reshape(-1), 0, self.spec.rows - 1)

    def miss_rows(self, ids, mask=None) -> np.ndarray:
        """Distinct global rows of this batch absent from the device cache
        (the set the staging buffer must resolve)."""
        rows = np.unique(self._global_rows(ids, mask))
        return rows[self._slot_of_row[rows] < 0]

    def stage(self, params: dict, ids, mask=None) -> dict:
        """Resolve this batch's cache misses into the staging buffer and
        return the param subtree with fresh staging tensors.

        The host gather only touches rows the async prefetch worker hasn't
        already staged (those count as prefetch hits in ``stats``); the
        device upload is skipped entirely when the staging area is
        unchanged. Raises :class:`StagingOverflowError` when the distinct
        miss set exceeds the buffer — callers serve in
        :meth:`split_for_staging` chunks instead.
        """
        miss = self.miss_rows(ids, mask)
        try:
            staged, already = self.pipeline.ensure(miss)
        except StagingOverflowError:
            self.stats.staging_overflows += 1
            raise
        self.stats.staged_rows += staged
        self.stats.prefetched_rows += already
        # wire bytes: what the staging upload actually moves per row
        # (d + 4 for int8 rows + their scale, 4·d full-precision)
        self.stats.h2d_bytes += staged * self.wire_row_bytes
        return {**params, **self._staging_leaves()}

    def prefetch_hint(self, ids, mask=None) -> None:
        """Queue an upcoming batch's rows for speculative off-thread
        staging (the engine calls this with batch t+1's rows while batch
        t's dense compute runs)."""
        self.pipeline.hint(self._global_rows(ids, mask))

    def split_for_staging(self, ids) -> list:
        """Split a (b, k) batch into row-contiguous chunks whose distinct
        miss sets each fit the staging buffer — the synchronous fallback
        for miss storms. Greedy; singleton chunks always fit because
        ``staging_capacity >= k * multi_hot``."""
        ids = np.asarray(ids)
        if ids.ndim == 1:
            ids = ids[None, :]
        chunks, start, covered = [], 0, set()
        for i in range(ids.shape[0]):
            miss = set(self.miss_rows(ids[i:i + 1]).tolist())
            if i > start and len(covered | miss) > self.staging_capacity:
                chunks.append(ids[start:i])
                start, covered = i, miss
            else:
                covered |= miss
        chunks.append(ids[start:])
        return chunks

    # -- lookup --------------------------------------------------------------
    def lookup(self, params: dict, ids: jax.Array, offsets: jax.Array, *,
               strategy: str = "auto",
               interpret: bool | None = None) -> jax.Array:
        if self.quantized:
            return kops.multi_table_lookup_host_q8(
                ids, params["cache"], params["cache_scale"],
                params["staging"], params["staging_scale"],
                params["slot_of_row"], params["staging_slot_of_row"],
                offsets, strategy=strategy, interpret=interpret)
        return kops.multi_table_lookup_host(
            ids, params["cache"], params["staging"], params["slot_of_row"],
            params["staging_slot_of_row"], offsets,
            strategy=strategy, interpret=interpret)

    def lookup_multihot(self, params: dict, ids: jax.Array, mask: jax.Array,
                        offsets: jax.Array, *, strategy: str = "auto",
                        interpret: bool | None = None) -> jax.Array:
        if self.quantized:
            return kops.multi_table_lookup_host_q8_multihot(
                ids, mask, params["cache"], params["cache_scale"],
                params["staging"], params["staging_scale"],
                params["slot_of_row"], params["staging_slot_of_row"],
                offsets, strategy=strategy, interpret=interpret)
        return kops.multi_table_lookup_host_multihot(
            ids, mask, params["cache"], params["staging"],
            params["slot_of_row"], params["staging_slot_of_row"], offsets,
            strategy=strategy, interpret=interpret)

    # -- traffic / cache management ------------------------------------------
    def observe(self, global_rows: np.ndarray) -> None:
        rows = np.clip(np.asarray(global_rows).reshape(-1),
                       0, self._counts.size - 1)
        np.add.at(self._counts, rows, 1)
        hits = int((self._slot_of_row[rows] >= 0).sum())
        self.stats.hits += hits
        self.stats.misses += rows.size - hits
        self._observe_traffic(rows)

    def refresh(self, params: dict) -> dict:
        """Re-admit the C most frequent observed rows into the device
        cache (deterministic tie-break by row id), gathering their values
        from the *host* backing, and evict the promoted rows from staging
        — hot staged rows graduate to the cache tier. Returns the full
        fresh subtree for the double-buffered publish."""
        order = np.lexsort((np.arange(self._counts.size), -self._counts))
        hot = np.sort(order[:self.capacity]).astype(np.int32)
        new_map = np.full(self._counts.size, -1, dtype=np.int32)
        new_map[hot] = np.arange(self.capacity, dtype=np.int32)
        self._slot_of_row = new_map
        self.pipeline.drop(hot)
        self.stats.refreshes += 1
        return self.device_params()

    def apply_deltas(self, params: dict, row_ids, new_rows
                     ) -> tuple[dict, int]:
        """Write online trainer deltas through all three tiers.

        The host backing (RAM array or writable memmap) is updated in
        place — under the prefetch pipeline's staging lock, so a
        concurrent ``ensure``/``hint`` gather can never see a half-written
        row — and any of the updated rows already sitting in staging slots
        are re-gathered before the lock drops (stale staged copies would
        otherwise serve until eviction). Cached rows get their device
        cache slot rewritten functionally, and the returned subtree
        carries fresh staging leaves (the pipeline version bump forces the
        re-upload). Quantized stores re-quantize the incoming fp32 rows
        once, updating the scale sidecar alongside the int8 payload.

        One sharing caveat the A/B scenario must know: unlike
        ``CachedStore`` — whose device tensors are immutable, so a second
        engine's published subtree stays pinned pre-delta — the host
        backing is *store state shared by every engine serving through
        this object*; staged rows re-gathered after a delta see the new
        values on every engine. Version-pinned A/B needs device-resident
        stores (or two host stores over separate backings).
        """
        rows_idx, vals = validate_deltas(self.spec, row_ids, new_rows)
        n = int(rows_idx.size)
        if n == 0:
            return params, 0
        backing = self.host_view()
        if not backing.flags.writeable:
            if isinstance(backing, np.memmap):
                raise ValueError(
                    "host backing is a read-only memmap "
                    "(HostBackedStore.open defaults to mode='r'); reopen "
                    "with mode='r+' to accept online deltas")
            # adopt() aliased the source table zero-copy (np.asarray of a
            # device array is read-only): promote to a private writable
            # copy once, on the first push
            self._backing = backing = backing.copy()
        if self.quantized:
            q, scale = quant.quantize_rows(np.asarray(vals))
            self.stats.quant_rows += n
            wire = q

            def write():
                backing[rows_idx] = q
                self.host_scale_view()[rows_idx] = scale
        else:
            wire = np.asarray(vals)

            def write():
                backing[rows_idx] = wire
        self.pipeline.apply_backing_update(rows_idx, write)
        out = dict(params)
        slots = self._slot_of_row[rows_idx]
        cached = np.flatnonzero(slots >= 0)
        if cached.size:
            cidx = jnp.asarray(slots[cached])
            out["cache"] = params["cache"].at[cidx].set(
                jnp.asarray(wire[cached]))
            if self.quantized:
                out["cache_scale"] = params["cache_scale"].at[cidx].set(
                    jnp.asarray(scale[cached]))
        # fresh staging leaves: a bumped pipeline version re-uploads the
        # refreshed slots; untouched staging reuses the previous upload
        out.update(self._staging_leaves())
        self.stats.delta_rows += n
        return out, n

    @property
    def cached_traffic_fraction(self) -> float:
        total = int(self._counts.sum())
        if not total:
            return 0.0
        return float(self._counts[self._slot_of_row >= 0].sum()) / total

    def device_bytes(self, params: dict) -> int:
        """Bytes of embedding state resident on device — the budget the
        benchmark asserts stays put while ``rows`` grows (cache + staging
        rows plus the two int32 maps; the backing is absent)."""
        return sum(int(np.prod(params[k].shape)
                       * np.dtype(params[k].dtype).itemsize)
                   for k in self.runtime_keys)

    def describe(self) -> str:
        tier3 = ",mmap" if self.backing_path else ""
        q = ",int8" if self.quantized else ""
        return (f"host(C={self.capacity},S={self.staging_capacity},"
                f"rows={self.spec.rows},d={self.spec.dim}{tier3}{q})")
