"""FusedEmbeddingSpec — static description of a CTR embedding module.

Lives in the ``repro.embedding`` subsystem (it is the contract every
:class:`~repro.embedding.store.EmbeddingStore` is built against);
``repro.core`` re-exports it for convenience.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["FusedEmbeddingSpec"]


@dataclasses.dataclass(frozen=True)
class FusedEmbeddingSpec:
    """Static description of a CTR embedding module.

    Attributes:
        field_sizes: number of features n_i per field (len = k).
        dim:         shared embedding dimension d.
        multi_hot:   max ids per field (1 = one-hot fields).
        dtype:       parameter dtype.
        pad_rows_to: pad the mega-table height to a multiple (sharding).
        row_dtype:   *wire* dtype of stored rows — ``None`` (default) keeps
                     rows in ``dtype`` (bit-exact); ``"int8"`` stores rows
                     symmetrically quantized with one fp32 scale per row
                     (``repro.quant``), dequantized inside the gather.
                     A store-side memory-system choice: two specs differing
                     only in ``row_dtype`` describe the same model.
    """
    field_sizes: tuple[int, ...]
    dim: int
    multi_hot: int = 1
    dtype: str = "float32"
    pad_rows_to: int = 1
    row_dtype: str | None = None

    def __post_init__(self):
        if self.row_dtype not in (None, "int8"):
            raise ValueError(f"row_dtype must be None or 'int8', "
                             f"got {self.row_dtype!r}")

    @property
    def k(self) -> int:
        return len(self.field_sizes)

    @property
    def rows(self) -> int:
        """Mega-table height: all fields + 1 zero row (multi-hot masking),
        padded up for even sharding."""
        n = int(sum(self.field_sizes)) + 1
        pad = self.pad_rows_to
        return ((n + pad - 1) // pad) * pad

    @property
    def offsets(self) -> np.ndarray:
        return np.concatenate(
            [[0], np.cumsum(self.field_sizes)[:-1]]).astype(np.int32)

    @property
    def zero_row(self) -> int:
        return int(sum(self.field_sizes))

    @property
    def quantized(self) -> bool:
        """True when stored rows travel as int8 + per-row fp32 scale."""
        return self.row_dtype == "int8"

    @property
    def wire_row_bytes(self) -> int:
        """Bytes one row costs on the wire (gather / host→device staging):
        ``4·d`` for fp32 rows, ``d + 4`` for int8 rows (payload + scale)."""
        if self.quantized:
            return self.dim + 4
        return self.dim * np.dtype(self.dtype).itemsize

    @property
    def n_params(self) -> int:
        return self.rows * self.dim
