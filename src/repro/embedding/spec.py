"""FusedEmbeddingSpec — static description of a CTR embedding module.

Lives in the ``repro.embedding`` subsystem (it is the contract every
:class:`~repro.embedding.store.EmbeddingStore` is built against);
``repro.core`` re-exports it for convenience.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["FusedEmbeddingSpec"]


@dataclasses.dataclass(frozen=True)
class FusedEmbeddingSpec:
    """Static description of a CTR embedding module.

    Attributes:
        field_sizes: number of features n_i per field (len = k).
        dim:         shared embedding dimension d.
        multi_hot:   max ids per field (1 = one-hot fields).
        dtype:       parameter dtype.
        pad_rows_to: pad the mega-table height to a multiple (sharding).
    """
    field_sizes: tuple[int, ...]
    dim: int
    multi_hot: int = 1
    dtype: str = "float32"
    pad_rows_to: int = 1

    @property
    def k(self) -> int:
        return len(self.field_sizes)

    @property
    def rows(self) -> int:
        """Mega-table height: all fields + 1 zero row (multi-hot masking),
        padded up for even sharding."""
        n = int(sum(self.field_sizes)) + 1
        pad = self.pad_rows_to
        return ((n + pad - 1) // pad) * pad

    @property
    def offsets(self) -> np.ndarray:
        return np.concatenate(
            [[0], np.cumsum(self.field_sizes)[:-1]]).astype(np.int32)

    @property
    def zero_row(self) -> int:
        return int(sum(self.field_sizes))

    @property
    def n_params(self) -> int:
        return self.rows * self.dim
