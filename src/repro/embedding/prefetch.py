"""PrefetchPipeline — async host→device miss resolution for HostBackedStore.

The HugeCTR inference-parameter-server pattern (arXiv:2210.08804): when the
backing embedding table lives out of device memory, cache misses must not
stall the gather. This module owns the host side of that pipeline:

  * a **staging area** of ``S`` host-resident row slots mirroring the
    device staging buffer, with an LRU map ``row -> slot``;
  * an **async worker** that takes hints (the id rows of not-yet-served
    batches) and resolves their cache misses — gathers the missed rows
    from the host backing into staging slots — *while the previous
    batch's dense compute runs on device*;
  * a synchronous ``ensure`` used at serve time to close any remaining
    gap, so the device lookup never sees an unresolved row.

The store (``repro.embedding.host.HostBackedStore``) snapshots the staging
area into two runtime tensors per served batch — ``staging (S, d)`` and
``staging_slot_of_row (rows,)`` — published through the same
double-buffered swap as a cache refresh, so compiled plans survive every
batch with zero recompiles. When a batch's distinct miss set cannot fit
the ``S`` slots, ``ensure`` raises :class:`StagingOverflowError` and the
caller falls back to a synchronous chunked host gather
(``HostBackedStore.split_for_staging``) instead of serving wrong scores.

Thread safety: one lock guards the staging area (the serve thread's
``ensure``/``snapshot`` vs the worker's speculative staging); counters are
read under the same lock. Snapshots copy, so tensors already uploaded for
an in-flight batch can never be mutated behind the device's back.
"""

from __future__ import annotations

import threading
from collections import OrderedDict, deque

import numpy as np

__all__ = ["StagingOverflowError", "PrefetchPipeline"]


class StagingOverflowError(RuntimeError):
    """A batch's distinct miss set exceeds the staging buffer's capacity.

    Raised by :meth:`PrefetchPipeline.ensure` (and surfaced through
    ``HostBackedStore.stage``). Callers must fall back to a synchronous
    chunked host gather — never serve the batch with unresolved rows.
    """


class PrefetchPipeline:
    """Host-side staging area + async miss-resolution worker.

    Args:
        store: the owning ``HostBackedStore`` — read for the live host
            backing table and the current cache index map (both change on
            adopt/refresh, so they are read per operation, never bound).
        capacity: number of staging row slots ``S``.

    The pipeline never touches the device: it fills a host staging buffer
    and bumps a version counter; the store turns dirty snapshots into
    fresh device tensors (and reuses the previous upload when nothing
    changed — an all-hit batch moves zero bytes).
    """

    def __init__(self, store, capacity: int):
        if capacity < 1:
            raise ValueError(f"staging capacity must be >= 1, got {capacity}")
        self._store = store
        self.capacity = int(capacity)
        spec = store.spec
        # the buffer holds *wire*-format rows: int8 payload (+ fp32 scale
        # sidecar) for quantized stores, full-precision rows otherwise —
        # so staging h2d traffic shrinks with the representation
        wire_dtype = np.int8 if spec.quantized else np.dtype(spec.dtype)
        self._buf = np.zeros((self.capacity, spec.dim), dtype=wire_dtype)
        self._sbuf = (np.zeros((self.capacity, 1), dtype=np.float32)
                      if spec.quantized else None)
        self._slot_of_staged = np.full(spec.rows, -1, dtype=np.int32)
        self._lru: OrderedDict[int, int] = OrderedDict()   # row -> slot
        self._free = list(range(self.capacity - 1, -1, -1))
        self._lock = threading.Lock()
        self._version = 0          # bumps on any buffer/map change
        # async worker
        self._q: deque[np.ndarray] = deque()
        self._cv = threading.Condition()
        self._thread: threading.Thread | None = None
        self._running = False
        self._idle = threading.Event()
        self._idle.set()
        # counters (read under _lock; mirrored into StoreStats by the store)
        self.n_prefetched = 0      # rows staged by the async worker
        self.n_hinted_batches = 0

    # -- staging area --------------------------------------------------------
    @property
    def version(self) -> int:
        with self._lock:
            return self._version

    def staged_rows(self) -> int:
        with self._lock:
            return len(self._lru)

    def _stage_rows_locked(self, need: np.ndarray, miss_set: set) -> int:
        """Gather ``need`` backing rows into free/evicted slots. Caller
        holds the lock and has verified the miss set fits."""
        backing = self._store.host_view()
        scales = self._store.host_scale_view() if self._sbuf is not None \
            else None
        staged = 0
        for row in need:
            row = int(row)
            if self._slot_of_staged[row] >= 0:      # raced with the worker
                self._lru.move_to_end(row)
                continue
            if self._free:
                slot = self._free.pop()
            else:
                # evict the least-recently-used row NOT in this miss set
                victim = next(r for r in self._lru if r not in miss_set)
                slot = self._lru.pop(victim)
                self._slot_of_staged[victim] = -1
            self._buf[slot] = backing[row]
            if scales is not None:
                self._sbuf[slot] = scales[row]
            self._slot_of_staged[row] = slot
            self._lru[row] = slot
            staged += 1
        if staged:
            self._version += 1
        return staged

    def ensure(self, miss_rows: np.ndarray) -> tuple[int, int]:
        """Make every row in ``miss_rows`` staged; returns
        ``(n_newly_staged, n_already_staged)``.

        ``miss_rows`` are unique global rows absent from the device cache.
        Rows already resolved (by a previous batch or the async worker)
        are free — they count as prefetch hits. Raises
        :class:`StagingOverflowError` when the set cannot fit ``S`` slots.
        """
        miss_rows = np.asarray(miss_rows).reshape(-1)
        if miss_rows.size > self.capacity:
            raise StagingOverflowError(
                f"batch misses {miss_rows.size} distinct uncached rows; "
                f"staging buffer holds {self.capacity} — serve in chunks "
                "(split_for_staging) or raise staging_capacity")
        with self._lock:
            need = miss_rows[self._slot_of_staged[miss_rows] < 0]
            already = int(miss_rows.size - need.size)
            # refresh LRU position of reused rows so hot staged rows survive
            for row in miss_rows[self._slot_of_staged[miss_rows] >= 0]:
                self._lru.move_to_end(int(row))
            staged = self._stage_rows_locked(need, set(miss_rows.tolist()))
        return staged, already

    def snapshot(self) -> tuple[np.ndarray, np.ndarray | None,
                                np.ndarray, int]:
        """Copy of ``(staging_buf, scale_buf_or_None, slot_of_staged,
        version)`` — safe to upload while the worker keeps staging for
        later batches. The scale sidecar is ``None`` for full-precision
        stores."""
        with self._lock:
            sbuf = self._sbuf.copy() if self._sbuf is not None else None
            return self._buf.copy(), sbuf, self._slot_of_staged.copy(), \
                self._version

    def apply_backing_update(self, rows: np.ndarray, write) -> int:
        """Run ``write()`` (a host-backing mutation covering ``rows``)
        under the staging lock, then re-gather any of those rows already
        sitting in staging slots so the buffer never serves stale values.

        The lock ordering is the point: the worker's speculative staging
        and the serve thread's ``ensure`` gather backing rows under this
        same lock, so the in-place backing write can never be observed
        half-done — a staged row is either entirely pre-delta or entirely
        post-delta. Returns how many staged slots were refreshed; any
        refresh bumps the version so the store's next snapshot re-uploads.
        """
        rows = np.asarray(rows).reshape(-1)
        with self._lock:
            write()
            backing = self._store.host_view()
            scales = self._store.host_scale_view() if self._sbuf is not None \
                else None
            refreshed = 0
            for row in rows:
                slot = int(self._slot_of_staged[int(row)])
                if slot < 0:
                    continue
                self._buf[slot] = backing[int(row)]
                if scales is not None:
                    self._sbuf[slot] = scales[int(row)]
                refreshed += 1
            if refreshed:
                self._version += 1
            return refreshed

    def drop(self, rows: np.ndarray) -> int:
        """Evict ``rows`` from staging (refresh promoted them into the
        device cache — their slots are better spent on cold rows)."""
        dropped = 0
        with self._lock:
            for row in np.asarray(rows).reshape(-1):
                row = int(row)
                slot = self._lru.pop(row, None)
                if slot is not None:
                    self._slot_of_staged[row] = -1
                    self._free.append(slot)
                    dropped += 1
            if dropped:
                self._version += 1
        return dropped

    # -- async worker --------------------------------------------------------
    def hint(self, miss_rows: np.ndarray) -> None:
        """Queue candidate rows for speculative staging off-thread.

        Best-effort: the worker stages what fits into currently-free (or
        LRU-evictable) slots and silently skips the rest — ``ensure`` at
        serve time closes any gap. Starts the daemon worker lazily and
        restarts it after a ``stop``.
        """
        rows = np.asarray(miss_rows).reshape(-1)
        if rows.size == 0:
            return
        with self._cv:
            self._q.append(rows)
            self._idle.clear()
            if self._thread is None or not self._thread.is_alive():
                self._running = True
                self._thread = threading.Thread(
                    target=self._worker_loop, daemon=True,
                    name="embedding-prefetch")
                self._thread.start()
            self._cv.notify()

    def stop(self) -> None:
        """Stop the worker thread (joins). Later hints restart it."""
        with self._cv:
            self._running = False
            self._cv.notify_all()
        t, self._thread = self._thread, None
        if t is not None and t.is_alive():
            t.join()
        self._idle.set()

    def wait_idle(self, timeout: float | None = 5.0) -> bool:
        """Block until the hint queue is drained (tests/benchmarks use
        this to make prefetch counters deterministic)."""
        return self._idle.wait(timeout)

    def _worker_loop(self) -> None:
        while True:
            with self._cv:
                while self._running and not self._q:
                    self._idle.set()
                    self._cv.wait()
                if not self._running:
                    self._idle.set()
                    return
                rows = self._q.popleft()
            try:
                self._prefetch(rows)
            except Exception:
                # speculative work only — ensure() redoes anything missed
                pass

    def _prefetch(self, rows: np.ndarray) -> None:
        """Stage the cache misses of a hinted batch, capped at what fits."""
        slot_of_row = self._store.cache_map_view()
        rows = np.unique(rows)
        miss = rows[slot_of_row[rows] < 0]
        if miss.size == 0:
            return
        with self._lock:
            need = miss[self._slot_of_staged[miss] < 0]
            # cap at free + evictable (never evict rows this hint needs)
            budget = len(self._free) + max(
                0, len(self._lru) - int((self._slot_of_staged[miss] >= 0)
                                        .sum()))
            need = need[:budget]
            n = self._stage_rows_locked(need, set(miss.tolist()))
            self.n_prefetched += n
            self.n_hinted_batches += 1
