"""CachedStore — HugeCTR-style hot-row cache over a backing mega-table.

Two tiers, one index map:

  ``backing``     (rows, d)  the full mega-table (conceptually host/HBM).
  ``cache``       (C, d)     device-resident copies of the C hottest rows.
  ``slot_of_row`` (rows,)    int32 index map: cache slot of each global
                             row, -1 when the row is not cached.

A lookup is one *two-level gather*: cached rows are gathered from the
cache, misses fall through to the backing store — on TPU via the
scalar-prefetch Pallas kernel ``mtl_gather_two_level`` (the miss
fall-through happens in the BlockSpec index map, so hits never touch
backing rows beyond row 0), on CPU via the identical-math jnp path.

Bit-exactness by construction: cache rows are verbatim copies of backing
rows, so ``CachedStore`` and ``DenseStore`` built from the same key are
value-identical on every input — which cache state is live only changes
*where* a row is read from, never what is read (paper Table I discipline).

Admission/refresh follows the zipf skew of observed traffic: the store
counts served row frequencies host-side (``observe``), and ``refresh``
rebuilds the cache with the C most frequent rows (deterministic tie-break
by row id). Until the first refresh the cache seeds with the lowest C row
ids — the right prior for CTR id streams, where popular items cluster at
small ids (both the synthetic quadratic skew and zipf traffic do).

Quantized tier (``row_dtype="int8"``): both tiers hold int8 rows with one
fp32 scale per row (``backing_scale (rows, 1)`` / ``cache_scale (C, 1)``,
symmetric absmax via ``repro.quant``), quantized **once** at init/adopt —
cache rows stay verbatim copies of quantized backing rows, so tier choice
still never changes values *within the int8 representation*; what relaxes
is fp32 bit-exactness (round-trip error ≤ scale/2 per element, gated
model-level by ``benchmarks/accuracy_parity.py --quant``). The gather
moves ``d + 4`` bytes per row instead of ``4·d`` and dequantizes in-kernel
(``mtl_gather_two_level_q8``). Scales are runtime inputs like everything
else, so refresh stays recompile-free.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import quant
from repro.kernels import ops as kops

from .spec import FusedEmbeddingSpec
from .store import EmbeddingStore, validate_deltas

__all__ = ["CachedStore"]


class CachedStore(EmbeddingStore):
    """Hot-row cache of capacity ``C`` rows over the full backing table.

    The store keeps a host-side mirror of the index map plus per-row
    traffic counts; ``refresh`` is the only operation that changes cache
    contents, and it returns a *new* param subtree — a double buffer:
    the fresh cache/index tensors are built on the side while readers
    keep serving from the old ones, then the caller publishes the new
    subtree in one reference swap (``InferenceEngine.refresh_cache``).
    Because all three tensors are ``runtime_keys``, compiled plans take
    them as per-call inputs and survive the swap untouched — a refresh
    costs two device uploads, never a recompile.

    Multi-chip: ``partition_spec`` keeps ``backing`` row-sharded
    (vocab-parallel over the model axis) with ``cache``/``slot_of_row``
    replicated. ``refresh`` works on a *placed* backing unchanged — the
    eager gather in ``_with_cache`` reads across shards — and the caller
    (``InferenceEngine.refresh_cache``) republishes the fresh subtree
    through :meth:`EmbeddingStore.place` so the swap lands on the exact
    shardings every compiled plan was lowered against.
    """

    refreshable = True
    runtime_keys = ("cache", "backing", "slot_of_row")

    def __init__(self, spec: FusedEmbeddingSpec, capacity: int,
                 row_dtype: str | None = None):
        if row_dtype is not None:
            spec = dataclasses.replace(spec, row_dtype=row_dtype)
        super().__init__(spec)
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(min(capacity, spec.rows))
        self._counts = np.zeros(spec.rows, dtype=np.int64)
        self._slot_of_row = self._seed_map()
        if self.quantized:
            # scales are plan runtime inputs exactly like their rows, so a
            # refresh republishes them through the same recompile-free swap
            self.runtime_keys = ("cache", "cache_scale", "backing",
                                 "backing_scale", "slot_of_row")

    def _seed_map(self) -> np.ndarray:
        m = np.full(self.spec.rows, -1, dtype=np.int32)
        m[:self.capacity] = np.arange(self.capacity, dtype=np.int32)
        return m

    # -- params ------------------------------------------------------------
    def init(self, key: jax.Array) -> dict:
        return self.from_dense({"mega_table": self.init_dense_table(key)})

    def from_dense(self, dense_params: dict) -> dict:
        """Adopt a DenseStore subtree (``{"mega_table": table}``) into the
        tiered layout, caching per the store's current index map. Quantized
        stores quantize the whole table here, **once** — every later
        refresh reuses these rows/scales, so tier contents stay verbatim
        copies of one quantization."""
        backing = dense_params["mega_table"]
        backing_scale = None
        if self.quantized:
            backing, backing_scale = self._quantize_table(backing)
        return self._with_cache(backing, self._slot_of_row, backing_scale)

    def adopt(self, params: dict) -> dict:
        if "backing" not in params:
            return self.from_dense(params)
        backing = params["backing"]
        if self.quantized and backing.dtype != jnp.int8:
            backing, backing_scale = self._quantize_table(backing)
        else:
            backing_scale = params.get("backing_scale")
        return self._with_cache(backing, self._slot_of_row, backing_scale)

    def _quantize_table(self, table: jax.Array
                        ) -> tuple[jax.Array, jax.Array]:
        q, scale = quant.quantize_rows(table)
        self.stats.quant_rows += int(table.shape[0])
        return q, scale

    def _with_cache(self, backing: jax.Array, slot_of_row: np.ndarray,
                    backing_scale: jax.Array | None = None) -> dict:
        hot = np.flatnonzero(slot_of_row >= 0)
        cached_rows = hot[np.argsort(slot_of_row[hot])]   # row of slot s
        if cached_rows.size != self.capacity:
            raise ValueError(f"index map holds {cached_rows.size} slots, "
                             f"capacity is {self.capacity}")
        rows = jnp.asarray(cached_rows)
        out = {"backing": backing,
               "cache": jnp.take(backing, rows, axis=0),
               "slot_of_row": jnp.asarray(slot_of_row)}
        if self.quantized:
            if backing_scale is None:
                raise ValueError("quantized store needs backing_scale "
                                 "alongside its int8 backing")
            out["backing_scale"] = backing_scale
            out["cache_scale"] = jnp.take(backing_scale, rows, axis=0)
        return out

    def partition_spec(self, model_axis: str | None = "model") -> dict:
        """Backing row-sharded (vocab-parallel); the hot cache, the index
        map, and the per-row scales are small and latency-critical —
        replicated (scales placed like ``slot_of_row``)."""
        spec = {"backing": P(model_axis, None),
                "cache": P(),
                "slot_of_row": P()}
        if self.quantized:
            spec["backing_scale"] = P()
            spec["cache_scale"] = P()
        return spec

    def dense_view(self, params: dict) -> jax.Array:
        if self.quantized:
            # the naive level / serial baselines want fp32 rows — rebuild
            # them from the int8 grid so every path sees identical values
            return quant.dequantize_rows(
                params["backing"], params["backing_scale"]).astype(
                    jnp.dtype(self.spec.dtype))
        return params["backing"]

    # -- lookup ------------------------------------------------------------
    def lookup(self, params: dict, ids: jax.Array, offsets: jax.Array, *,
               strategy: str = "auto",
               interpret: bool | None = None) -> jax.Array:
        if self.quantized:
            return kops.multi_table_lookup_cached_q8(
                ids, params["cache"], params["cache_scale"],
                params["backing"], params["backing_scale"],
                params["slot_of_row"], offsets,
                strategy=strategy, interpret=interpret)
        return kops.multi_table_lookup_cached(
            ids, params["cache"], params["backing"], params["slot_of_row"],
            offsets, strategy=strategy, interpret=interpret)

    def lookup_multihot(self, params: dict, ids: jax.Array, mask: jax.Array,
                        offsets: jax.Array, *, strategy: str = "auto",
                        interpret: bool | None = None) -> jax.Array:
        if self.quantized:
            return kops.multi_table_lookup_cached_q8_multihot(
                ids, mask, params["cache"], params["cache_scale"],
                params["backing"], params["backing_scale"],
                params["slot_of_row"], offsets,
                strategy=strategy, interpret=interpret)
        return kops.multi_table_lookup_cached_multihot(
            ids, mask, params["cache"], params["backing"],
            params["slot_of_row"], offsets,
            strategy=strategy, interpret=interpret)

    # -- traffic / cache management ---------------------------------------
    def observe(self, global_rows: np.ndarray) -> None:
        # clip like the gather does (jnp.take clamps), so one malformed id
        # can't wedge the serving loop; O(b·k) — no full-vocab allocation
        # per batch (np.bincount(minlength=rows) would be O(vocab))
        rows = np.clip(np.asarray(global_rows).reshape(-1),
                       0, self._counts.size - 1)
        np.add.at(self._counts, rows, 1)
        hits = int((self._slot_of_row[rows] >= 0).sum())
        self.stats.hits += hits
        self.stats.misses += rows.size - hits
        self._observe_traffic(rows)

    def refresh(self, params: dict) -> dict:
        """Re-admit the C most frequent observed rows (ties -> lower row id
        wins, so refresh is deterministic for any traffic history)."""
        order = np.lexsort((np.arange(self._counts.size), -self._counts))
        hot = np.sort(order[:self.capacity]).astype(np.int32)
        new_map = np.full(self._counts.size, -1, dtype=np.int32)
        new_map[hot] = np.arange(self.capacity, dtype=np.int32)
        self._slot_of_row = new_map
        self.stats.refreshes += 1
        return self._with_cache(params["backing"], new_map,
                                params.get("backing_scale"))

    def apply_deltas(self, params: dict, row_ids, new_rows
                     ) -> tuple[dict, int]:
        """Scatter online trainer deltas into backing **and** cache.

        Functional (``.at[].set`` builds new arrays): the subtree handed
        back shares every untouched row with the old one, and the caller
        publishes it through the double-buffered swap — readers of the old
        subtree keep a consistent pre-delta view, so a torn update is
        impossible by construction. Rows currently cached get their cache
        slot rewritten too (cache rows stay verbatim copies of backing
        rows — the tier invariant deltas must preserve); the index map is
        untouched, so admission state survives value updates. Quantized
        stores re-quantize the incoming fp32 rows **once** here
        (``repro.quant``), updating the per-row scales alongside the int8
        payloads.
        """
        rows_idx, vals = validate_deltas(self.spec, row_ids, new_rows)
        n = int(rows_idx.size)
        if n == 0:
            return params, 0
        idx = jnp.asarray(rows_idx)
        out = dict(params)
        if self.quantized:
            q, scale = quant.quantize_rows(np.asarray(vals))
            self.stats.quant_rows += n
            wire = jnp.asarray(q)
            out["backing"] = params["backing"].at[idx].set(wire)
            out["backing_scale"] = \
                params["backing_scale"].at[idx].set(jnp.asarray(scale))
        else:
            wire = jnp.asarray(vals)
            out["backing"] = params["backing"].at[idx].set(wire)
        slots = self._slot_of_row[rows_idx]
        cached = np.flatnonzero(slots >= 0)
        if cached.size:
            cidx = jnp.asarray(slots[cached])
            out["cache"] = params["cache"].at[cidx].set(
                wire[jnp.asarray(cached)])
            if self.quantized:
                out["cache_scale"] = params["cache_scale"].at[cidx].set(
                    jnp.asarray(scale[cached]))
        self.stats.delta_rows += n
        return out, n

    @property
    def cached_traffic_fraction(self) -> float:
        """Share of observed traffic mass landing on currently-cached rows
        — the counter that grows with skew at fixed capacity (zipf mass
        concentrates in the top-C). O(rows): read it lazily (refresh time,
        stats dumps), not per served batch — engines do."""
        total = int(self._counts.sum())
        if not total:
            return 0.0
        return float(self._counts[self._slot_of_row >= 0].sum()) / total

    def describe(self) -> str:
        q = ",int8" if self.quantized else ""
        return (f"cached(C={self.capacity},rows={self.spec.rows},"
                f"d={self.spec.dim}{q})")
