"""CachedStore — HugeCTR-style hot-row cache over a backing mega-table.

Two tiers, one index map:

  ``backing``     (rows, d)  the full mega-table (conceptually host/HBM).
  ``cache``       (C, d)     device-resident copies of the C hottest rows.
  ``slot_of_row`` (rows,)    int32 index map: cache slot of each global
                             row, -1 when the row is not cached.

A lookup is one *two-level gather*: cached rows are gathered from the
cache, misses fall through to the backing store — on TPU via the
scalar-prefetch Pallas kernel ``mtl_gather_two_level`` (the miss
fall-through happens in the BlockSpec index map, so hits never touch
backing rows beyond row 0), on CPU via the identical-math jnp path.

Bit-exactness by construction: cache rows are verbatim copies of backing
rows, so ``CachedStore`` and ``DenseStore`` built from the same key are
value-identical on every input — which cache state is live only changes
*where* a row is read from, never what is read (paper Table I discipline).

Admission/refresh follows the zipf skew of observed traffic: the store
counts served row frequencies host-side (``observe``), and ``refresh``
rebuilds the cache with the C most frequent rows (deterministic tie-break
by row id). Until the first refresh the cache seeds with the lowest C row
ids — the right prior for CTR id streams, where popular items cluster at
small ids (both the synthetic quadratic skew and zipf traffic do).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ops as kops

from .spec import FusedEmbeddingSpec
from .store import EmbeddingStore

__all__ = ["CachedStore"]


class CachedStore(EmbeddingStore):
    """Hot-row cache of capacity ``C`` rows over the full backing table.

    The store keeps a host-side mirror of the index map plus per-row
    traffic counts; ``refresh`` is the only operation that changes cache
    contents, and it returns a *new* param subtree — a double buffer:
    the fresh cache/index tensors are built on the side while readers
    keep serving from the old ones, then the caller publishes the new
    subtree in one reference swap (``InferenceEngine.refresh_cache``).
    Because all three tensors are ``runtime_keys``, compiled plans take
    them as per-call inputs and survive the swap untouched — a refresh
    costs two device uploads, never a recompile.

    Multi-chip: ``partition_spec`` keeps ``backing`` row-sharded
    (vocab-parallel over the model axis) with ``cache``/``slot_of_row``
    replicated. ``refresh`` works on a *placed* backing unchanged — the
    eager gather in ``_with_cache`` reads across shards — and the caller
    (``InferenceEngine.refresh_cache``) republishes the fresh subtree
    through :meth:`EmbeddingStore.place` so the swap lands on the exact
    shardings every compiled plan was lowered against.
    """

    refreshable = True
    runtime_keys = ("cache", "backing", "slot_of_row")

    def __init__(self, spec: FusedEmbeddingSpec, capacity: int):
        super().__init__(spec)
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(min(capacity, spec.rows))
        self._counts = np.zeros(spec.rows, dtype=np.int64)
        self._slot_of_row = self._seed_map()

    def _seed_map(self) -> np.ndarray:
        m = np.full(self.spec.rows, -1, dtype=np.int32)
        m[:self.capacity] = np.arange(self.capacity, dtype=np.int32)
        return m

    # -- params ------------------------------------------------------------
    def init(self, key: jax.Array) -> dict:
        return self.from_dense({"mega_table": self.init_dense_table(key)})

    def from_dense(self, dense_params: dict) -> dict:
        """Adopt a DenseStore subtree (``{"mega_table": table}``) into the
        tiered layout, caching per the store's current index map."""
        backing = dense_params["mega_table"]
        return self._with_cache(backing, self._slot_of_row)

    def adopt(self, params: dict) -> dict:
        if "backing" in params:
            return self._with_cache(params["backing"], self._slot_of_row)
        return self.from_dense(params)

    def _with_cache(self, backing: jax.Array,
                    slot_of_row: np.ndarray) -> dict:
        hot = np.flatnonzero(slot_of_row >= 0)
        cached_rows = hot[np.argsort(slot_of_row[hot])]   # row of slot s
        if cached_rows.size != self.capacity:
            raise ValueError(f"index map holds {cached_rows.size} slots, "
                             f"capacity is {self.capacity}")
        return {"backing": backing,
                "cache": jnp.take(backing, jnp.asarray(cached_rows), axis=0),
                "slot_of_row": jnp.asarray(slot_of_row)}

    def partition_spec(self, model_axis: str | None = "model") -> dict:
        """Backing row-sharded (vocab-parallel); the hot cache and the
        index map are small and latency-critical — replicated."""
        return {"backing": P(model_axis, None),
                "cache": P(),
                "slot_of_row": P()}

    def dense_view(self, params: dict) -> jax.Array:
        return params["backing"]

    # -- lookup ------------------------------------------------------------
    def lookup(self, params: dict, ids: jax.Array, offsets: jax.Array, *,
               strategy: str = "auto",
               interpret: bool | None = None) -> jax.Array:
        return kops.multi_table_lookup_cached(
            ids, params["cache"], params["backing"], params["slot_of_row"],
            offsets, strategy=strategy, interpret=interpret)

    def lookup_multihot(self, params: dict, ids: jax.Array, mask: jax.Array,
                        offsets: jax.Array, *, strategy: str = "auto",
                        interpret: bool | None = None) -> jax.Array:
        return kops.multi_table_lookup_cached_multihot(
            ids, mask, params["cache"], params["backing"],
            params["slot_of_row"], offsets,
            strategy=strategy, interpret=interpret)

    # -- traffic / cache management ---------------------------------------
    def observe(self, global_rows: np.ndarray) -> None:
        # clip like the gather does (jnp.take clamps), so one malformed id
        # can't wedge the serving loop; O(b·k) — no full-vocab allocation
        # per batch (np.bincount(minlength=rows) would be O(vocab))
        rows = np.clip(np.asarray(global_rows).reshape(-1),
                       0, self._counts.size - 1)
        np.add.at(self._counts, rows, 1)
        hits = int((self._slot_of_row[rows] >= 0).sum())
        self.stats.hits += hits
        self.stats.misses += rows.size - hits

    def refresh(self, params: dict) -> dict:
        """Re-admit the C most frequent observed rows (ties -> lower row id
        wins, so refresh is deterministic for any traffic history)."""
        order = np.lexsort((np.arange(self._counts.size), -self._counts))
        hot = np.sort(order[:self.capacity]).astype(np.int32)
        new_map = np.full(self._counts.size, -1, dtype=np.int32)
        new_map[hot] = np.arange(self.capacity, dtype=np.int32)
        self._slot_of_row = new_map
        self.stats.refreshes += 1
        return self._with_cache(params["backing"], new_map)

    @property
    def cached_traffic_fraction(self) -> float:
        """Share of observed traffic mass landing on currently-cached rows
        — the counter that grows with skew at fixed capacity (zipf mass
        concentrates in the top-C). O(rows): read it lazily (refresh time,
        stats dumps), not per served batch — engines do."""
        total = int(self._counts.sum())
        if not total:
            return 0.0
        return float(self._counts[self._slot_of_row >= 0].sum()) / total

    def describe(self) -> str:
        return (f"cached(C={self.capacity},rows={self.spec.rows},"
                f"d={self.spec.dim})")
