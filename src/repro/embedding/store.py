"""EmbeddingStore — the parameter-server tier behind the fused lookup.

DPIFrame's Alg.-1 mega-table assumes the whole concatenated table sits in
fast memory; production CTR vocabularies don't fit. HugeCTR's inference
parameter server (arXiv:2210.08804) answers with a tiered design: a small
device-resident cache of hot rows over a larger backing store, exploiting
the zipf skew of real CTR traffic. This module is that tier for the repro:

  ``EmbeddingStore``  the abstraction every embedding consumer talks to —
                      parameter init/placement, one-hot and multi-hot
                      lookup, traffic observation, cache bookkeeping.
  ``DenseStore``      today's monolithic mega-table (the default): one
                      ``mega_table`` leaf, every lookup one fused gather.
  ``CachedStore``     (``repro.embedding.cached``) hot-row cache of
                      capacity C + full backing table + index map.
  ``HostBackedStore`` (``repro.embedding.host``) hot-row cache + per-batch
                      staging buffer on device; the backing table stays in
                      host memory (or on disk via mmap) and misses are
                      resolved by an async prefetch pipeline.

``FusedEmbeddingCollection`` delegates all lookups and parameter handling
to its store, so the whole stack — ``kernels/ops.py`` →
``embedding/collection.py`` → ``core/plan.py`` → ``serving/engine.py`` —
is store-agnostic.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ops as kops

from .spec import FusedEmbeddingSpec

__all__ = ["StoreStats", "EmbeddingStore", "DenseStore", "runtime_edge",
           "validate_deltas"]


def runtime_edge(prefix: str, leaf: str) -> str:
    """Graph-input edge name of one runtime store tensor.

    Refreshable stores expose their tensors (cache/backing/index map) as
    *runtime inputs* of compiled plans instead of baked constants, so a
    cache refresh is a tensor swap rather than a recompile. Everything
    that names those edges — model graph emission, ``compile_plan``'s AOT
    input spec, the engine's per-call bindings — goes through this one
    function so the convention can never drift.
    """
    return f"{prefix}:{leaf}"


def validate_deltas(spec: FusedEmbeddingSpec, row_ids, new_rows
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Canonicalize one ``(row_id, new_row)`` delta batch.

    Shared by every store's ``apply_deltas``: ``row_ids`` become a unique
    int64 vector (duplicates keep the **last** occurrence — the stream is
    ordered, and a scatter with duplicate indices has no defined winner),
    ``new_rows`` the matching ``(n, d)`` full-precision array. Rejects
    out-of-range ids and — hard — any id at or past ``spec.zero_row``:
    the zero row and the padding rows must stay zero for multi-hot
    masking, so a trainer can never push values into them.
    """
    row_ids = np.asarray(row_ids, dtype=np.int64).reshape(-1)
    rows = np.asarray(new_rows, dtype=np.dtype(spec.dtype))
    if rows.ndim == 1:
        rows = rows[None, :]
    if rows.shape != (row_ids.size, spec.dim):
        raise ValueError(f"delta rows shape {rows.shape} != "
                         f"{(row_ids.size, spec.dim)}")
    if row_ids.size == 0:
        return row_ids, rows
    if row_ids.min() < 0 or row_ids.max() >= spec.zero_row:
        bad = row_ids[(row_ids < 0) | (row_ids >= spec.zero_row)]
        raise ValueError(
            f"delta row ids {bad[:8].tolist()} out of range [0, "
            f"{spec.zero_row}) — the zero row and padding rows must stay "
            "zero (multi-hot masking depends on it)")
    # keep the LAST occurrence of each duplicated id (stream order wins)
    _, first_in_reversed = np.unique(row_ids[::-1], return_index=True)
    keep = row_ids.size - 1 - first_in_reversed
    return row_ids[keep], rows[keep]


@dataclasses.dataclass
class StoreStats:
    """Host-side traffic counters of one embedding store.

    ``hits``/``misses`` count *row lookups* (b·k per one-hot batch) against
    the store's current index map; ``refreshes`` counts cache rebuilds.
    All zero (and staying zero) for ``DenseStore``.

    The staging counters are live only for stores with ``needs_staging``:
    ``staged_rows`` counts rows gathered host-side at serve time (synchronous
    — the prefetch worker didn't get there first), ``prefetched_rows`` rows
    already resolved when the batch arrived, ``h2d_bytes`` the host→device
    staging traffic those synchronous rows cost, and ``staging_overflows``
    batches whose miss set exceeded the staging buffer (served via the
    chunked fallback).

    All byte counters are **wire** bytes — dtype-aware via the spec's
    ``wire_row_bytes`` (``4·d`` for fp32 rows, ``d + 4`` for int8 rows +
    their fp32 scale), never an fp32 assumption. ``gather_bytes`` accounts
    the device-side gather traffic of observed lookups (rows × wire bytes);
    the ``quant_*`` pair is nonzero only for quantized stores:
    ``quant_rows`` counts rows pushed through ``repro.quant`` at
    init/adopt/refresh/delta time, ``quant_bytes_saved`` the gather bytes
    the int8 representation avoided vs full-precision rows.

    ``delta_rows`` counts rows whose *values* changed through
    :meth:`EmbeddingStore.apply_deltas` (online trainer pushes) — distinct
    from ``refreshes``, which only re-admits existing values.
    """
    hits: int = 0
    misses: int = 0
    refreshes: int = 0
    staged_rows: int = 0
    prefetched_rows: int = 0
    h2d_bytes: int = 0
    staging_overflows: int = 0
    gather_bytes: int = 0
    quant_rows: int = 0
    quant_bytes_saved: int = 0
    delta_rows: int = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        n = self.lookups
        return self.hits / n if n else 0.0


class EmbeddingStore:
    """Interface of the embedding parameter tier.

    A store owns (a) the *layout* of embedding parameters — what leaves its
    param subtree contains and how they shard over a mesh — and (b) the
    *lookup* that turns per-field ids into embedding rows. Implementations
    must be bit-exact with each other: a store is a memory-system choice,
    never a numerics choice (paper Table I discipline).
    """

    spec: FusedEmbeddingSpec
    #: True when the store keeps a rebuildable cache tier — engines only
    #: run the observe/refresh loop for refreshable stores.
    refreshable: bool = False
    #: Param-subtree leaves that compiled plans must take as *runtime
    #: inputs* (per-call arguments) rather than bake as constants, so a
    #: ``refresh`` can swap them without invalidating any compiled plan.
    #: Empty for stores that never refresh (their tensors may be baked).
    runtime_keys: tuple = ()
    #: True when the store cannot resolve every lookup from device-resident
    #: tensors alone — the serve path must call :meth:`stage` with each
    #: batch's ids *before* the lookup (and may call :meth:`prefetch_hint`
    #: with upcoming batches to move that work off the critical path).
    needs_staging: bool = False

    def __init__(self, spec: FusedEmbeddingSpec):
        self.spec = spec
        self.stats = StoreStats()

    @property
    def quantized(self) -> bool:
        """True when this store's rows travel as int8 + per-row fp32 scale
        (``spec.row_dtype == "int8"``). Quantized stores relax the
        bit-exactness contract to the accuracy-parity gate — the gather
        dequantizes in-kernel, so scores differ from fp32 by at most the
        per-row round-trip error (≤ scale/2 per element)."""
        return self.spec.quantized

    @property
    def wire_row_bytes(self) -> int:
        """Bytes one row moves on gather / host→device staging."""
        return self.spec.wire_row_bytes

    def _observe_traffic(self, rows: np.ndarray) -> None:
        """Wire-byte accounting shared by every tiered store's ``observe``:
        ``rows`` are the clipped global rows this batch gathered."""
        self.stats.gather_bytes += rows.size * self.wire_row_bytes
        if self.quantized:
            full = self.spec.dim * np.dtype(self.spec.dtype).itemsize
            self.stats.quant_bytes_saved += rows.size * (
                full - self.wire_row_bytes)

    # -- params ------------------------------------------------------------
    def init(self, key: jax.Array) -> dict:
        """Fresh parameter subtree for this store."""
        raise NotImplementedError

    def init_dense_table(self, key: jax.Array) -> jax.Array:
        """The canonical (rows, d) mega-table init shared by every store
        (so Dense/Cached params built from one key are value-identical)."""
        spec = self.spec
        # flat small std, production-CTR style (fan-in scaling belongs to
        # the MLP, not the table). Row magnitude also sets the int8
        # absmax grid step, so oversized rows would punish quantized tiers.
        scale = 0.05
        table = jax.random.normal(
            key, (spec.rows, spec.dim), dtype=jnp.dtype(spec.dtype)) * scale
        # zero row (and padding rows) must stay zero for multi-hot masking
        return table.at[spec.zero_row:].set(0.0)

    def adopt(self, params: dict) -> dict:
        """Convert another store's param subtree into this store's layout
        (values preserved bit-for-bit — a store swap is a placement change,
        not a re-init). Engines use this to retrofit a cache onto a model
        whose params were built dense."""
        raise NotImplementedError

    def partition_spec(self, model_axis: str | None = "model") -> dict:
        """PartitionSpec subtree matching :meth:`init`'s structure."""
        raise NotImplementedError

    def place(self, params: dict, mesh, model_axis: str | None = "model"
              ) -> dict:
        """``device_put`` the param subtree onto ``mesh`` per
        :meth:`partition_spec` (vocab-parallel tables, replicated cache
        tiers), dropping mesh axes a leaf's dim doesn't divide.

        The **mesh-aware refresh primitive**: a refresh builds fresh
        tensors host-side, and the engine places them here before the
        double-buffered swap so it publishes *placed* tensors, never
        unplaced host arrays. The specs are re-derived from the same
        ``partition_spec`` the compile-time placement used, so they match
        the shardings recorded on every plan (``runtime_shardings``);
        were they ever to diverge, the plan step's per-call ``device_put``
        re-places the tensors — a cross-device copy on the hot path, not
        a recompile or a wrong answer (tests pin the match).
        """
        from repro.distributed.sharding import fit_spec
        from jax.sharding import NamedSharding
        if mesh is None:
            return params
        axis = model_axis if model_axis in mesh.axis_names else None
        specs = self.partition_spec(axis)
        return {k: jax.device_put(
                    v, NamedSharding(mesh, fit_spec(mesh, specs[k], v.shape)))
                for k, v in params.items()}

    def dense_view(self, params: dict) -> jax.Array:
        """The full (rows, d) table — the serial/naive level and the
        sharded shard_map path gather straight from it."""
        raise NotImplementedError

    # -- lookup ------------------------------------------------------------
    def lookup(self, params: dict, ids: jax.Array, offsets: jax.Array, *,
               strategy: str = "auto",
               interpret: bool | None = None) -> jax.Array:
        """ids (b, k) -> (b, k*d)."""
        raise NotImplementedError

    def lookup_multihot(self, params: dict, ids: jax.Array, mask: jax.Array,
                        offsets: jax.Array, *, strategy: str = "auto",
                        interpret: bool | None = None) -> jax.Array:
        """ids/mask (b, k, h) -> (b, k*d) sum-pooled."""
        raise NotImplementedError

    # -- staging (only meaningful when ``needs_staging``) -------------------
    def stage(self, params: dict, ids, mask=None) -> dict:
        """Resolve this batch's misses into device-reachable tensors and
        return the param subtree to serve it with. No-op pass-through for
        stores whose device tensors already cover every row."""
        return params

    def prefetch_hint(self, ids, mask=None) -> None:
        """Hint that ``ids`` will be served soon — staging stores resolve
        their misses off-thread while earlier batches compute. No-op."""

    def split_for_staging(self, ids) -> list:
        """Split a batch into chunks each of which :meth:`stage` can
        resolve — the fallback after a staging overflow. Trivial single
        chunk for non-staging stores."""
        return [np.asarray(ids)]

    # -- traffic / cache management ---------------------------------------
    def observe(self, global_rows: np.ndarray) -> None:
        """Record served row traffic (host-side; outside jit)."""

    def refresh(self, params: dict) -> dict:
        """Rebuild any cache tier from observed traffic; returns the
        (possibly new) param subtree. No-op for cacheless stores."""
        return params

    def apply_deltas(self, params: dict, row_ids, new_rows
                     ) -> tuple[dict, int]:
        """Apply online ``(row_id, new_row)`` parameter deltas (a live
        trainer's incremental push) and return ``(fresh_subtree,
        n_rows_applied)``.

        The fresh subtree is built **on the side** — the caller publishes
        it through the same double-buffered swap as a refresh, so compiled
        plans survive every delta batch with zero recompiles. Incoming
        rows are always full-precision; quantized stores re-quantize them
        through ``repro.quant`` before publish. Only stores whose tensors
        are runtime plan inputs can support this — ``DenseStore`` bakes
        its ``mega_table`` into every compiled plan as a constant, so
        updated values could never reach a cached plan.
        """
        raise NotImplementedError(
            f"{type(self).__name__} does not support online deltas: its "
            "tensors are compiled into plans as constants, not runtime "
            "inputs. Serve through CachedStore or HostBackedStore (their "
            "tiers republish through the recompile-free swap).")

    @property
    def cached_traffic_fraction(self) -> float:
        """Fraction of *observed traffic mass* whose rows are currently
        cached (1.0 for a store that holds everything in one tier)."""
        return 1.0

    def describe(self) -> str:
        """Short identity string (stamped into plan keys and stats)."""
        raise NotImplementedError


class DenseStore(EmbeddingStore):
    """The monolithic mega-table: everything in one fast-memory tier.

    Param subtree: ``{"mega_table": (rows, d)}`` — exactly the layout the
    repo used before stores existed, so older callers that hand-build
    ``{"mega_table": table}`` dicts keep working unchanged.
    """

    def init(self, key: jax.Array) -> dict:
        return {"mega_table": self.init_dense_table(key)}

    def adopt(self, params: dict) -> dict:
        if "mega_table" in params:
            return params
        backing = params["backing"]
        if "backing_scale" in params and backing.dtype == jnp.int8:
            # a quantized tiered subtree: reconstitute full-precision rows
            # (lossy source — the int8 grid is all the values that remain)
            from repro import quant
            backing = quant.dequantize_rows(
                backing, params["backing_scale"]).astype(
                    jnp.dtype(self.spec.dtype))
        return {"mega_table": backing}

    def partition_spec(self, model_axis: str | None = "model") -> dict:
        """Row-sharded (vocab-parallel) placement of the mega-table."""
        return {"mega_table": P(model_axis, None)}

    def dense_view(self, params: dict) -> jax.Array:
        return params["mega_table"]

    def lookup(self, params: dict, ids: jax.Array, offsets: jax.Array, *,
               strategy: str = "auto",
               interpret: bool | None = None) -> jax.Array:
        return kops.multi_table_lookup(
            ids, params["mega_table"], offsets,
            strategy=strategy, interpret=interpret)

    def lookup_multihot(self, params: dict, ids: jax.Array, mask: jax.Array,
                        offsets: jax.Array, *, strategy: str = "auto",
                        interpret: bool | None = None) -> jax.Array:
        return kops.multi_table_lookup_multihot(
            ids, mask, params["mega_table"], offsets,
            strategy=strategy, interpret=interpret)

    def describe(self) -> str:
        return f"dense(rows={self.spec.rows},d={self.spec.dim})"
