"""FusedEmbeddingCollection — store-backed realization of paper Alg. 1.

All k per-field embedding tables are concatenated row-wise into ONE
mega-table; per-field ids become global rows via static offsets. One gather
(Pallas on TPU / single XLA gather on CPU) replaces k serial lookups —
contribution C2, with C3's output-first allocation inside the kernel.

Where the mega-table *lives* is the store's business
(:mod:`repro.embedding.store`): ``DenseStore`` holds it as one fast-memory
leaf, ``CachedStore`` splits it into a device-resident hot-row cache plus a
backing table. The collection delegates parameter init/placement and every
lookup to its store, so models, plans, and engines never see the tiers.

Distribution: the dense table (or the cached store's backing tier) is
*row-sharded* over the ``model`` mesh axis (vocab-parallel).
``apply_sharded`` performs the masked-local-gather + psum pattern under
``shard_map`` — the multi-chip generalization of Alg. 1; the same helper
serves LM vocab embeddings (a 1-table degenerate case).
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.kernels import ops as kops

from .spec import FusedEmbeddingSpec
from .store import DenseStore, EmbeddingStore

__all__ = ["FusedEmbeddingCollection", "sharded_vocab_lookup"]


class FusedEmbeddingCollection:
    """Lookup front-end over a pluggable :class:`EmbeddingStore`."""

    def __init__(self, spec: FusedEmbeddingSpec,
                 store: EmbeddingStore | None = None):
        self.spec = spec
        self.store = store if store is not None else DenseStore(spec)
        # row_dtype is the store's wire-format choice, not part of the
        # model's schema — two specs differing only there are compatible
        if dataclasses.replace(self.store.spec, row_dtype=None) != \
                dataclasses.replace(spec, row_dtype=None):
            raise ValueError("store was built for a different embedding "
                             f"spec: {self.store.spec} != {spec}")
        self._offsets = jnp.asarray(spec.offsets)

    # -- params ------------------------------------------------------------
    def init(self, key: jax.Array) -> dict:
        return self.store.init(key)

    def partition_spec(self, model_axis: str | None = "model") -> dict:
        """Mesh placement of the store's param subtree (vocab-parallel
        tables; cache tiers replicated)."""
        return self.store.partition_spec(model_axis)

    def dense_view(self, params: dict) -> jax.Array:
        """The full (rows, d) table, whichever tier holds it."""
        return self.store.dense_view(params)

    # -- single-device / replicated lookup ----------------------------------
    def apply(self, params: dict, ids: jax.Array, *,
              strategy: str = "auto", interpret: bool | None = None
              ) -> jax.Array:
        """ids (b, k) -> (b, k*d)."""
        return self.store.lookup(params, ids, self._offsets,
                                 strategy=strategy, interpret=interpret)

    def apply_multihot(self, params: dict, ids: jax.Array, mask: jax.Array,
                       *, strategy: str = "auto",
                       interpret: bool | None = None) -> jax.Array:
        """ids/mask (b, k, h) -> (b, k*d) sum-pooled."""
        return self.store.lookup_multihot(params, ids, mask, self._offsets,
                                          strategy=strategy,
                                          interpret=interpret)

    def apply_serial(self, params: dict, ids: jax.Array) -> jax.Array:
        """Baseline: k separate gathers + concat (PyTorch-A analogue)."""
        return kops.multi_table_lookup(
            ids, self.store.dense_view(params), self._offsets,
            strategy="serial")

    # -- traffic observation -------------------------------------------------
    def observe(self, ids: np.ndarray) -> None:
        """Feed served (b, k) id traffic to the store's admission counters
        (host-side numpy; call outside jit — engines do)."""
        ids = np.asarray(ids, dtype=np.int64)
        if ids.ndim == 1:
            ids = ids[None, :]
        self.store.observe(ids + self.spec.offsets[None, :])

    # -- distributed lookup --------------------------------------------------
    def apply_sharded(self, params: dict, ids: jax.Array, mesh: jax.sharding.Mesh,
                      *, model_axis: str = "model",
                      batch_axes: tuple[str, ...] = ("data",)) -> jax.Array:
        """Vocab-parallel fused lookup over the row-sharded dense tier.

        Each shard gathers locally (out-of-range rows masked to 0) and the
        partial results are summed over the model axis — one psum replaces
        k independent lookups' worth of gather traffic.
        """
        b, k = ids.shape
        d = self.spec.dim
        global_rows = (ids.astype(jnp.int32) + self._offsets[None, :])

        def _local(rows, table):
            axis_idx = jax.lax.axis_index(model_axis)
            shard_rows = table.shape[0]
            lo = axis_idx * shard_rows
            local = rows - lo
            valid = (local >= 0) & (local < shard_rows)
            safe = jnp.where(valid, local, 0)
            vals = jnp.take(table, safe.reshape(-1), axis=0)
            vals = vals.reshape(*rows.shape, d)
            vals = jnp.where(valid[..., None], vals, 0)
            return jax.lax.psum(vals, axis_name=model_axis)

        baxis = batch_axes if len(batch_axes) > 1 else batch_axes[0]
        fn = shard_map(
            _local, mesh=mesh,
            in_specs=(P(baxis, None), P(model_axis, None)),
            out_specs=P(baxis, None, None),
            check_vma=False)
        out = fn(global_rows, self.store.dense_view(params))
        return out.reshape(b, k * d)


def sharded_vocab_lookup(table: jax.Array, ids: jax.Array, *,
                         model_axis: str = "model") -> jax.Array:
    """shard_map-interior vocab-parallel lookup (LM embedding reuse).

    Call *inside* an existing shard_map / with sharded ``table`` rows:
    masked local gather + psum over ``model_axis``.

    Args:
        table: (rows_per_shard, d) local shard of the embedding table.
        ids:   (...,) global token ids.

    Returns:
        (..., d) embeddings, replicated over the model axis.
    """
    shard_rows = table.shape[0]
    axis_idx = jax.lax.axis_index(model_axis)
    lo = axis_idx * shard_rows
    local = ids.astype(jnp.int32) - lo
    valid = (local >= 0) & (local < shard_rows)
    safe = jnp.where(valid, local, 0)
    vals = jnp.take(table, safe.reshape(-1), axis=0)
    vals = vals.reshape(*ids.shape, table.shape[1])
    vals = jnp.where(valid[..., None], vals, 0)
    return jax.lax.psum(vals, axis_name=model_axis)
