"""repro.embedding — the tiered embedding parameter-server subsystem.

  spec.py        FusedEmbeddingSpec (static schema of a fused mega-table)
  store.py       EmbeddingStore abstraction + DenseStore (monolithic tier)
  cached.py      CachedStore (hot-row cache + backing table, HugeCTR-style)
  host.py        HostBackedStore (cache + staging on device, backing in
                 host memory or on disk — the out-of-HBM tier)
  prefetch.py    PrefetchPipeline (async host-side miss resolution)
  collection.py  FusedEmbeddingCollection — the lookup front-end models
                 emit graph ops against; delegates everything to its store

The rest of the stack is store-agnostic: models hold a collection, plans
place parameters via ``partition_spec()``, engines feed traffic back via
``observe``/``refresh`` (see ``repro.serving.engine``) and resolve staging
stores' misses via ``stage``/``prefetch_hint``.
"""

from .spec import FusedEmbeddingSpec
from .store import (DenseStore, EmbeddingStore, StoreStats, runtime_edge,
                    validate_deltas)
from .cached import CachedStore
from .host import HostBackedStore
from .prefetch import PrefetchPipeline, StagingOverflowError
from .collection import FusedEmbeddingCollection, sharded_vocab_lookup

__all__ = [
    "FusedEmbeddingSpec",
    "EmbeddingStore",
    "DenseStore",
    "CachedStore",
    "HostBackedStore",
    "PrefetchPipeline",
    "StagingOverflowError",
    "StoreStats",
    "FusedEmbeddingCollection",
    "sharded_vocab_lookup",
    "runtime_edge",
    "validate_deltas",
]
