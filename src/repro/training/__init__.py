"""Training substrate: optimizer, checkpointing, loop, compression, metrics."""

from .checkpoint import latest_step, restore_checkpoint, save_checkpoint
from .compression import compressed_psum_mean, make_compressed_dp_step
from .metrics import logloss, roc_auc
from .optimizer import AdamWConfig, TrainState, adamw_init, adamw_update
from .train_loop import TrainLoopConfig, run_train_loop

__all__ = [
    "latest_step", "restore_checkpoint", "save_checkpoint",
    "compressed_psum_mean", "make_compressed_dp_step",
    "logloss", "roc_auc",
    "AdamWConfig", "TrainState", "adamw_init", "adamw_update",
    "TrainLoopConfig", "run_train_loop",
]
