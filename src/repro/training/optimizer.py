"""AdamW with dtype-configurable moment states and global-norm clipping.

Built from scratch in JAX (no optax available offline). The moment states
mirror the parameter tree and inherit the parameter PartitionSpecs, so under
the production mesh they are fully sharded (ZeRO-style). ``state_dtype``
="bfloat16" halves optimizer HBM (the state-compression knob used for
llama4-maverick, EXPERIMENTS §Dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "TrainState"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float = 1.0
    state_dtype: str = "float32"     # "bfloat16" = optimizer-state compression


@dataclasses.dataclass
class TrainState:
    step: jax.Array
    params: Any
    m: Any
    v: Any

    def tree_flatten(self):
        return (self.step, self.params, self.m, self.v), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.step, s.params, s.m, s.v), None),
    lambda aux, c: TrainState(*c))


def adamw_init(params: Any, cfg: AdamWConfig) -> TrainState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dtype=dt)
    return TrainState(
        step=jnp.zeros((), dtype=jnp.int32),
        params=params,
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(state: TrainState, grads: Any,
                 cfg: AdamWConfig) -> tuple[TrainState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g
        v32 = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - cfg.lr * delta
        return newp.astype(p.dtype), m32.astype(sdt), v32.astype(sdt)

    flat_p, treedef = jax.tree.flatten(state.params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return (TrainState(step=step, params=new_p, m=new_m, v=new_v),
            {"grad_norm": gnorm})
