"""Fault-tolerant training loop: checkpoint/restart, deterministic replay,
straggler surfacing, preemption-safe writes.

Posture for 1000+ nodes (DESIGN §5): the loop holds NO state outside
(step, TrainState) — data is step-indexed (restart replays nothing), and
checkpoints are atomic. ``resume="auto"`` continues from the newest intact
checkpoint after any crash/preemption. Per-step wall-times are logged and
steps slower than ``straggler_factor`` × the running median are flagged
(on real fleets this feeds the scheduler's replace/reshard decision).
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable

import numpy as np
import jax

from .checkpoint import latest_step, restore_checkpoint, save_checkpoint

__all__ = ["TrainLoopConfig", "run_train_loop"]


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    resume: str = "auto"                # "auto" | "none"
    log_every: int = 10
    straggler_factor: float = 3.0
    keep_ckpts: int = 2


def run_train_loop(step_fn: Callable, state: Any, batch_fn: Callable,
                   cfg: TrainLoopConfig,
                   shardings: Any = None) -> tuple[Any, list[dict]]:
    """Run ``total_steps`` of ``step_fn(state, batch) -> (state, metrics)``.

    batch_fn(step) must be a pure function of the step index.
    Returns (final_state, history).
    """
    start = 0
    if cfg.resume == "auto":
        last = latest_step(cfg.ckpt_dir)
        if last is not None:
            state = restore_checkpoint(cfg.ckpt_dir, last, state, shardings)
            start = last
            print(f"[train] resumed from step {start}")
    history: list[dict] = []
    durations: list[float] = []
    for step in range(start, cfg.total_steps):
        t0 = time.perf_counter()
        batch = batch_fn(step)
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(jax.tree.leaves(metrics)[0])
        dt = time.perf_counter() - t0
        durations.append(dt)
        med = float(np.median(durations[-50:]))
        if len(durations) > 5 and dt > cfg.straggler_factor * med:
            print(f"[train] STRAGGLER step {step}: {dt*1e3:.1f}ms "
                  f"(median {med*1e3:.1f}ms)")
        rec = {"step": step + 1, "sec": dt,
               **{k: float(v) for k, v in metrics.items()}}
        history.append(rec)
        if (step + 1) % cfg.log_every == 0:
            print(f"[train] step {rec['step']} "
                  + " ".join(f"{k}={v:.4f}" for k, v in rec.items()
                             if k != "step"))
        if (step + 1) % cfg.ckpt_every == 0 or step + 1 == cfg.total_steps:
            save_checkpoint(cfg.ckpt_dir, step + 1, state)
            _gc_checkpoints(cfg.ckpt_dir, cfg.keep_ckpts)
    return state, history


def _gc_checkpoints(ckpt_dir: str, keep: int) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(n.split("_", 1)[1]) for n in os.listdir(ckpt_dir)
        if n.startswith("step_")
        and os.path.exists(os.path.join(ckpt_dir, n, "manifest.json")))
    import shutil
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)
