"""Gradient compression for data-parallel reduction (distributed-opt trick).

Int8 block-quantized psum under ``shard_map``: ranks agree on a shared
per-block scale (pmax — a tiny f32 reduction), quantize locally to int8,
sum the int32-widened payload over the data axis (wire bytes ≈ ¼ of f32),
and dequantize with the shared scale. 8-bit rounding error only — validated
in tests to ~1% relative against the exact psum.

The quantize/dequantize math itself lives in ``repro.quant`` — the same
symmetric-absmax codepath the quantized embedding stores use for their
int8 rows; this module only adds what is collective-specific (blocking,
the pmax'd shared scale, the int32-widened psum).

Under GSPMD the DP all-reduce is normally implicit in the backward; this
explicit form exists so deployments that are ICI-bound on the gradient
reduction (§Roofline collective term) can opt in per-tensor.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import quant
from repro.compat import shard_map

__all__ = ["compressed_psum_mean", "make_compressed_dp_step", "BLOCK"]

BLOCK = 256


def compressed_psum_mean(x: jax.Array, axis_name: str) -> jax.Array:
    """Mean-reduce ``x`` over ``axis_name`` with int8 wire format.

    Call inside shard_map / under a mapped axis.
    """
    dtype = x.dtype
    shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    # shared per-block scale across ranks (small f32 wire cost); the pmax
    # sits between the local absmax and the eps floor so every rank
    # quantizes against the same guarded scale
    local_scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / quant.QMAX
    scale = jnp.maximum(jax.lax.pmax(local_scale, axis_name),
                        quant.SCALE_EPS)
    q = quant.quantize(blocks, scale)
    # int8 payload summed in int32 (≤ 2^23 ranks before overflow)
    qs = jax.lax.psum(q.astype(jnp.int32), axis_name)
    ranks = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    out = (quant.dequantize(qs, scale) / ranks).reshape(-1)[:n]
    return out.reshape(shape).astype(dtype)


def make_compressed_dp_step(loss_fn, mesh: Mesh, axis: str = "data"):
    """Build a data-parallel grad step whose DP reduction uses the int8
    wire format: ``step(params, batch) -> (loss, grads)`` with params
    replicated, batch sharded over ``axis``, and the gradient mean computed
    by ``compressed_psum_mean`` instead of the implicit f32 all-reduce.
    """
    def _local(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        loss = jax.lax.pmean(loss, axis)
        grads = jax.tree.map(
            lambda g: compressed_psum_mean(g, axis), grads)
        return loss, grads

    def step(params, batch):
        pspec = jax.tree.map(lambda _: P(), params)
        bspec = jax.tree.map(
            lambda x: P(axis, *([None] * (x.ndim - 1))), batch)
        return shard_map(_local, mesh=mesh,
                         in_specs=(pspec, bspec),
                         out_specs=(P(), pspec),
                         check_vma=False)(params, batch)
    return step
