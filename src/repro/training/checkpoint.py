"""Step-atomic, mesh-agnostic checkpointing (fault tolerance substrate).

Layout: ``<dir>/step_<N>/`` holding one ``.npy`` per pytree leaf (named by
"/"-joined tree path, escaped) + ``manifest.json`` (paths, shapes, dtypes,
step). Writes go to ``<dir>/.tmp_step_<N>`` and are atomically ``rename``d —
a preempted writer never corrupts the latest checkpoint (restart-safety).

Resharding on load: leaves are materialized host-side and ``device_put``
with the *target* shardings, so a checkpoint taken on one mesh restores
onto any other (elastic scaling). On a real multi-host pod each host would
write its shard (same manifest format, per-host files) — single-process
container writes full arrays; the interface is identical.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any

import numpy as np
import jax

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]


def _leaf_key(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    """Atomic write of ``tree`` under step ``step``. Returns final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "leaves": []}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({
            "key": _leaf_key(path), "file": fname,
            "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                     # atomic on POSIX
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_"):
            # only count completed (manifest present) checkpoints
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                steps.append(int(name.split("_", 1)[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, target: Any,
                       shardings: Any = None) -> Any:
    """Restore into the structure of ``target`` (pytree of arrays or
    ShapeDtypeStructs). ``shardings`` (same structure, NamedShardings)
    reshards onto the current mesh."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_t, treedef = jax.tree_util.tree_flatten(target)
    if len(manifest["leaves"]) != len(flat_t):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, target has "
            f"{len(flat_t)} — structure mismatch")
    flat_s = (jax.tree_util.tree_flatten(shardings)[0]
              if shardings is not None else [None] * len(flat_t))
    out = []
    for meta, tgt, shd in zip(manifest["leaves"], flat_t, flat_s):
        arr = np.load(os.path.join(path, meta["file"]))
        if list(arr.shape) != list(tgt.shape):
            raise ValueError(f"leaf {meta['key']}: checkpoint shape "
                             f"{arr.shape} != target {tgt.shape}")
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr, dtype=tgt.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
