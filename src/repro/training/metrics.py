"""CTR evaluation metrics — AUC (rank statistic) and LogLoss (paper Table I)."""

from __future__ import annotations

import numpy as np

__all__ = ["roc_auc", "logloss"]


def roc_auc(labels: np.ndarray, scores: np.ndarray) -> float:
    """Mann-Whitney U formulation; ties get average ranks."""
    labels = np.asarray(labels).reshape(-1)
    scores = np.asarray(scores, dtype=np.float64).reshape(-1)
    pos = labels > 0.5
    n_pos = int(pos.sum())
    n_neg = len(labels) - n_pos
    if n_pos == 0 or n_neg == 0:
        return float("nan")
    order = np.argsort(scores, kind="mergesort")
    ranks = np.empty(len(scores), dtype=np.float64)
    sorted_scores = scores[order]
    i = 0
    r = 1.0
    while i < len(scores):
        j = i
        while j + 1 < len(scores) and sorted_scores[j + 1] == sorted_scores[i]:
            j += 1
        avg = (r + r + (j - i)) / 2.0
        ranks[order[i:j + 1]] = avg
        r += j - i + 1
        i = j + 1
    return float((ranks[pos].sum() - n_pos * (n_pos + 1) / 2.0)
                 / (n_pos * n_neg))


def logloss(labels: np.ndarray, probs: np.ndarray,
            eps: float = 1e-7) -> float:
    labels = np.asarray(labels, dtype=np.float64).reshape(-1)
    probs = np.clip(np.asarray(probs, dtype=np.float64).reshape(-1),
                    eps, 1 - eps)
    return float(-np.mean(labels * np.log(probs)
                          + (1 - labels) * np.log(1 - probs)))
