"""Static analyzer for compiled SPMD HLO text.

Why: ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified in
EXPERIMENTS §Methodology), so scan-over-layers programs under-report FLOPs
and collective bytes by ~L×. XLA records every loop's
``known_trip_count`` in the while op's backend_config — this module
propagates those multipliers through the computation call graph and
produces *loop-corrected* totals:

  * ``dot_flops``          2·M·N·K per dot (the MXU work; elementwise VPU
                           flops are excluded — ≤1–2% on these models)
  * ``collective_bytes``   per collective kind, result-shape bytes ×
                           enclosing loop trip product

Everything is derived from the per-device SPMD module, so totals are
per-device (the roofline divides by per-chip peaks directly).
"""

from __future__ import annotations

import math
import re
from collections import defaultdict

from .hw import DTYPE_BYTES

__all__ = ["parse_hlo", "HLOStats"]

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_WHILE = re.compile(
    r"while\(.*?condition=%([\w.\-]+),\s*body=%([\w.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"calls=%([\w.\-]+)")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(sig: str) -> int:
    """Sum byte sizes of every shape literal in ``sig`` (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _first_shape(sig: str):
    m = _SHAPE_RE.search(sig)
    if not m:
        return None, []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


class HLOStats:
    def __init__(self):
        self.dot_flops = 0.0
        self.collective_bytes = defaultdict(float)   # kind -> bytes
        self.collective_count = defaultdict(int)
        self.n_while = 0
        self.trip_counts: list[int] = []

    @property
    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))


def parse_hlo(text: str) -> HLOStats:
    # ---- split into computations -------------------------------------------
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in text.splitlines():
        m = _COMP_HDR.match(line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)

    # ---- instruction result shapes (for dot operand lookup) ----------------
    result_sig: dict[str, str] = {}
    for body in comps.values():
        for line in body:
            m = _INSTR.match(line)
            if m:
                result_sig[m.group(1)] = m.group(2)

    # ---- call graph with loop multipliers ------------------------------------
    # edges: computation -> [(callee, multiplier_factor)]
    edges: dict[str, list[tuple[str, float]]] = defaultdict(list)
    stats = HLOStats()
    for name, body in comps.items():
        for line in body:
            wm = _WHILE.search(line)
            if wm:
                cond, wbody = wm.groups()
                tm = _TRIP.search(line)
                trips = int(tm.group(1)) if tm else 1
                stats.n_while += 1
                stats.trip_counts.append(trips)
                edges[name].append((wbody, float(trips)))
                edges[name].append((cond, float(trips)))
                continue
            cm = _CALLS.search(line)
            if cm:
                edges[name].append((cm.group(1), 1.0))

    mult: dict[str, float] = defaultdict(float)
    if entry is None:
        entry = max(comps, key=lambda c: len(comps[c])) if comps else ""
    mult[entry] = 1.0
    # propagate in topological-ish order (iterate until fixpoint; the call
    # graph is a DAG so bounded by its depth)
    for _ in range(64):
        changed = False
        for src, outs in edges.items():
            if mult[src] == 0:
                continue
            for dst, f in outs:
                want = mult[src] * f
                if mult[dst] < want:
                    mult[dst] = want
                    changed = True
        if not changed:
            break

    # ---- dots and collectives --------------------------------------------------
    for name, body in comps.items():
        m_c = mult[name] if mult[name] > 0 else 1.0
        for line in body:
            im = _INSTR.match(line)
            if not im:
                continue
            sig = im.group(2)
            if " dot(" in sig or sig.startswith("dot("):
                flops = _dot_flops(sig, result_sig)
                stats.dot_flops += flops * m_c
                continue
            for kind in _COLLECTIVES:
                # match the op (avoid matching -start/-done twice: count
                # only the "-start" of async pairs, or the plain op)
                if re.search(rf"\b{kind}(-start)?\(", sig):
                    if f"{kind}-done" in sig:
                        break
                    stats.collective_bytes[kind] += _shape_bytes(
                        sig.split("(")[0]) * m_c
                    stats.collective_count[kind] += 1
                    break
    return stats


# lhs operand of a dot: an optional inline shape literal (newer HLO text
# prints ``dot(f32[8,8]{1,0} %lhs, ...)``; TPU layouts carry tiling such as
# ``{1,0:T(8,128)}``) followed by the operand name
_DOT_LHS = re.compile(
    r"dot\(\s*(?:[a-z0-9]+\[(?P<dims>[0-9,]*)\](?:\{[^}]*\})?\s+)?"
    r"%?(?P<name>[\w.\-]+)")


def _dot_flops(sig: str, result_sig: dict[str, str]) -> float:
    """2 · prod(result) · K from the dot signature + operand lookup."""
    dt, rdims = _first_shape(sig)
    if dt is None:
        return 0.0
    out_elems = math.prod(rdims) if rdims else 1
    # contraction size: lhs operand shape at lhs_contracting_dims
    ops = _DOT_LHS.search(sig)
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", sig)
    k = 1
    if ops and cm and cm.group(1):
        if ops.group("dims") is not None:          # inline operand shape
            ldims = [int(d) for d in ops.group("dims").split(",")
                     ] if ops.group("dims") else []
        else:                                       # name-only: look it up
            lhs_sig = result_sig.get(ops.group("name"), "")
            _, ldims = _first_shape(lhs_sig) if lhs_sig else (None, [])
        for ci in cm.group(1).split(","):
            ci = int(ci)
            if ci < len(ldims):
                k *= ldims[ci]
    return 2.0 * out_elems * k
