"""TPU v5e hardware constants (per chip) used by the roofline model."""

PEAK_FLOPS_BF16 = 197e12        # FLOP/s
PEAK_OPS_INT8 = 394e12          # OP/s — the MXU doubles throughput at int8
HBM_BW = 819e9                  # B/s
ICI_BW_PER_LINK = 50e9          # B/s (per the assignment: ~50 GB/s/link)
HBM_BYTES = 16 * 2**30          # 16 GiB

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
