"""Roofline analysis from compiled dry-run artifacts."""

from . import hw
from .analytic import analytic_cost, model_flops, param_stats
from .hlo_parse import parse_hlo
from .roofline import RooflineReport, analyze_cell

__all__ = ["hw", "analytic_cost", "model_flops", "param_stats",
           "parse_hlo", "RooflineReport", "analyze_cell"]
