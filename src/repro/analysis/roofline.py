"""Roofline assembly: three terms per (arch × shape × mesh) from the
compiled dry-run artifact + the analytic model.

  compute_s    = corrected per-device dot FLOPs / 197 TF/s
  memory_s     = analytic per-device HBM bytes / 819 GB/s
  collective_s = corrected per-device collective bytes / 50 GB/s per link

Corrected = loop-trip multiplied (repro.analysis.hlo_parse); raw
cost_analysis numbers are reported alongside for transparency. The
MODEL_FLOPS / corrected-FLOPs ratio surfaces remat & redundancy waste
(remat alone puts it near 3/4 for training: 6ND useful vs ~8ND executed).
"""

from __future__ import annotations

import dataclasses
import json

from . import hw
from .analytic import analytic_cost
from .hlo_parse import parse_hlo

__all__ = ["RooflineReport", "analyze_cell"]


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # three terms (seconds per step)
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    # accounting
    hlo_dot_flops_per_device: float
    raw_cost_analysis_flops: float
    model_flops_global: float
    useful_ratio: float             # MODEL_FLOPS / corrected HLO flops
    collective_bytes_per_device: float
    collective_breakdown: dict
    hbm_bytes_per_device: float
    hbm_components: dict
    # memory feasibility (from memory_analysis)
    arg_bytes: int
    temp_bytes: int
    out_bytes: int
    fits_hbm: bool
    n_micro: int
    note: str = ""
    # int8 companion terms (quantized compute, ``compute_dtype="int8"``):
    # the MXU's int8 peak doubles bf16, and the weights-read HBM component
    # shrinks to ~1/4 (int8 payload + per-channel scales). Arithmetic
    # intensity (FLOP per HBM byte) for both dtypes locates each cell
    # against the machine balance point (PEAK / HBM_BW); defaulted so
    # pre-existing dry-run records still deserialize.
    compute_s_int8: float = 0.0
    memory_s_int8: float = 0.0
    arith_intensity: float = 0.0
    arith_intensity_int8: float = 0.0

    def step_time_bound_s(self) -> float:
        """Roofline lower bound on step time (no overlap assumption)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def roofline_fraction(self) -> float:
        """compute_term / max-term: 1.0 = perfectly compute-bound."""
        t = self.step_time_bound_s()
        return self.compute_s / t if t > 0 else 0.0

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=1, default=str)


def analyze_cell(arch: str, shape: str, mesh_name: str, chips: int,
                 compiled, n_micro: int = 1) -> RooflineReport:
    text = compiled.as_text()
    stats = parse_hlo(text)
    from repro.compat import cost_analysis
    ca = cost_analysis(compiled)
    ma = compiled.memory_analysis()
    an = analytic_cost(arch, shape, chips, n_micro)

    compute_s = stats.dot_flops / hw.PEAK_FLOPS_BF16
    memory_s = an.hbm_bytes_per_device / hw.HBM_BW
    collective_s = stats.total_collective_bytes / hw.ICI_BW_PER_LINK

    # int8 twin: matmuls at the doubled MXU peak, weight reads at ~1/4 the
    # bytes (the only HBM component quantized compute shrinks — activations
    # and embedding gathers are unchanged by the matmul dtype)
    w_read = float(an.components.get("weights_read", 0.0))
    hbm_int8 = an.hbm_bytes_per_device - 0.75 * w_read
    compute_s_int8 = stats.dot_flops / hw.PEAK_OPS_INT8
    memory_s_int8 = hbm_int8 / hw.HBM_BW
    ai = (stats.dot_flops / an.hbm_bytes_per_device
          if an.hbm_bytes_per_device else 0.0)
    ai_int8 = stats.dot_flops / hbm_int8 if hbm_int8 else 0.0
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)

    per_dev_model = an.model_flops / chips
    useful = per_dev_model / stats.dot_flops if stats.dot_flops else 0.0

    live = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
            + ma.output_size_in_bytes)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant,
        hlo_dot_flops_per_device=stats.dot_flops,
        raw_cost_analysis_flops=float(ca.get("flops", 0.0)),
        model_flops_global=an.model_flops,
        useful_ratio=useful,
        collective_bytes_per_device=stats.total_collective_bytes,
        collective_breakdown={k: v for k, v in
                              stats.collective_bytes.items()},
        hbm_bytes_per_device=an.hbm_bytes_per_device,
        hbm_components=an.components,
        arg_bytes=ma.argument_size_in_bytes,
        temp_bytes=ma.temp_size_in_bytes,
        out_bytes=ma.output_size_in_bytes,
        fits_hbm=live <= hw.HBM_BYTES,
        n_micro=n_micro,
        compute_s_int8=compute_s_int8,
        memory_s_int8=memory_s_int8,
        arith_intensity=ai,
        arith_intensity_int8=ai_int8,
    )
