"""Closed-form cost model per (arch × shape): MODEL_FLOPS and the HBM-traffic
estimate that feeds the roofline memory term.

MODEL_FLOPS follows the assignment: 6·N·D for training (N = active
non-embedding params, D = tokens), 2·N·D for prefill, 2·N·B for one decode
step. The HBM model is a documented lower-bound estimate (weights traffic +
optimizer traffic + activation-carry IO + KV/state traffic); assumptions are
listed field by field in EXPERIMENTS.md §Methodology.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import numpy as np

from repro.configs import SHAPES, get_config
from repro.models.lm import make_lm_model

__all__ = ["param_stats", "model_flops", "hbm_bytes_per_device",
           "AnalyticCost", "analytic_cost"]

_DT = {"float32": 4, "bfloat16": 2, "float16": 2}


def _n(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def param_stats(arch: str) -> dict:
    """total / active / embedding parameter counts (exact, via eval_shape)."""
    cfg = get_config(arch)
    model = make_lm_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    total = _n(shapes)
    embed = 0
    for name in ("embed", "lm_head", "pos_dec"):
        if name in shapes:
            embed += int(np.prod(shapes[name].shape))
    active = total
    if cfg.n_experts:
        expert = 0
        layers = shapes["layers"]
        for name in ("w_gate", "w_up", "w_down"):
            expert += int(np.prod(layers["moe"][name].shape))
        active = total - expert + int(expert * cfg.top_k / cfg.n_experts)
    return {"total": total, "active": active, "embed": embed,
            "param_bytes": total * _DT[cfg.dtype]}


def model_flops(arch: str, shape: str) -> float:
    """Global MODEL_FLOPS for one step of this cell."""
    cfg = get_config(arch)
    cell = SHAPES[shape]
    st = param_stats(arch)
    n = st["active"] - st["embed"]
    if cell.kind == "train":
        return 6.0 * n * cell.batch * cell.seq
    if cell.kind == "prefill":
        return 2.0 * n * cell.batch * cell.seq
    return 2.0 * n * cell.batch               # decode: one token per row


@dataclasses.dataclass
class AnalyticCost:
    model_flops: float            # global
    hbm_bytes_per_device: float   # per device per step
    components: dict


def hbm_bytes_per_device(arch: str, shape: str, chips: int,
                         n_micro: int = 1,
                         opt_state_bytes_per_param: int = 8) -> AnalyticCost:
    cfg = get_config(arch)
    cell = SHAPES[shape]
    st = param_stats(arch)
    pb = st["param_bytes"]
    act_dt = _DT[cfg.dtype]
    d = cfg.d_model
    L = cfg.n_layers + cfg.encoder_layers
    comp: dict[str, float] = {}

    if cell.kind == "train":
        # weights: fwd + remat-fwd + bwd ≈ 3 reads per microbatch
        comp["weights_read"] = 3.0 * n_micro * pb / chips
        # optimizer: read+write m, v (state dtype) + read+write params
        comp["optimizer"] = (2 * 2 * st["total"] * opt_state_bytes_per_param / 2
                             + 2 * pb) / chips
        comp["grads"] = 2 * st["total"] * 4 / chips       # f32 accum rw
        # activation carry: written fwd, read bwd, once per layer over the
        # whole global batch (microbatching doesn't change the total)
        comp["activations"] = 2.0 * L * cell.batch * cell.seq * d * act_dt / chips
    elif cell.kind == "prefill":
        comp["weights_read"] = pb / chips
        comp["activations"] = 2.0 * L * cell.batch * cell.seq * d * act_dt / chips
        comp["kv_write"] = _cache_bytes(arch, cell) / chips
    else:  # decode
        comp["weights_read"] = _decode_weight_bytes(arch) / chips
        cb = _cache_bytes(arch, cell)
        comp["cache_read"] = cb / chips
        comp["cache_write"] = min(cb / chips, 1e7)  # one-slot update
        comp["activations"] = 2.0 * L * cell.batch * d * act_dt / chips
    return AnalyticCost(model_flops=model_flops(arch, shape),
                        hbm_bytes_per_device=float(sum(comp.values())),
                        components=comp)


def _cache_bytes(arch: str, cell) -> float:
    """KV / SSM state bytes for the full cache at this cell's shape."""
    from repro.configs import input_specs
    cfg = get_config(arch)
    if cell.kind == "decode":
        specs = input_specs(arch, cell.name)
        total = 0
        for leaf in jax.tree.leaves(specs["cache"]):
            total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        return float(total)
    # prefill: KV written for (batch, seq); layers that actually hold KV
    if cfg.attention == "none":
        return 0.0
    if cfg.family == "hybrid":
        model = make_lm_model(cfg)
        layers_kv = model.n_shared()
    elif cfg.family == "encdec":
        layers_kv = 2 * cfg.n_layers           # self + cross per dec layer
    else:
        layers_kv = cfg.n_layers
    kv = 2 * (layers_kv * cell.batch * cell.seq
              * cfg.n_kv_heads * cfg.hd) * _DT[cfg.dtype]
    return float(kv)


def _decode_weight_bytes(arch: str) -> float:
    """Weights actually read per decode step (MoE reads routed experts only
    when batch << experts; with batch ≥ experts assume all touched)."""
    st = param_stats(arch)
    return float(st["param_bytes"])


def analytic_cost(arch: str, shape: str, chips: int,
                  n_micro: int = 1) -> AnalyticCost:
    return hbm_bytes_per_device(arch, shape, chips, n_micro)
