"""llama4-maverick-400b-a17b - [hf:meta-llama/Llama-4-Scout-17B-16E; unverified] MoE, early fusion"""

from repro.models.lm.config import LMConfig

SOURCE = "[hf:meta-llama/Llama-4-Scout-17B-16E; unverified] MoE, early fusion"

CONFIG = LMConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    n_experts=128,
    top_k=1,
    moe_token_replicate=True,
)
