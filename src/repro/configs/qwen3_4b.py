"""qwen3-4b - [hf:Qwen/Qwen3-8B; hf] qk_norm, GQA"""

from repro.models.lm.config import LMConfig

SOURCE = "[hf:Qwen/Qwen3-8B; hf] qk_norm, GQA"

CONFIG = LMConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=9728,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
)
