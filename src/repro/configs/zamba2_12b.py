"""zamba2-1.2b - [arXiv:2411.15242; hf] Mamba2 + shared attn blocks"""

from repro.models.lm.config import LMConfig

SOURCE = "[arXiv:2411.15242; hf] Mamba2 + shared attn blocks"

CONFIG = LMConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    ssm_head_dim=64,
    shared_attn_every=6,
    attention="hybrid",
)
