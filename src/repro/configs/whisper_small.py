"""whisper-small - [arXiv:2212.04356; unverified] enc-dec, conv frontend (stub)"""

from repro.models.lm.config import LMConfig

SOURCE = "[arXiv:2212.04356; unverified] enc-dec, conv frontend (stub)"

CONFIG = LMConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,            # decoder
    encoder_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
)
