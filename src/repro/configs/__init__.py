"""Architecture registry: 10 assigned LM architectures + paper CTR configs.

Every assigned arch lives in its own module (exact published config, with
``[source; tier]`` provenance) and is selectable via ``--arch <id>``.
``input_specs(arch, shape)`` returns ShapeDtypeStruct stand-ins for every
step-function input — weak-type-correct, shardable, no device allocation.

Shape cells (LM):
    train_4k     seq 4096   global_batch 256   lowers train_step
    prefill_32k  seq 32768  global_batch 32    lowers prefill
    decode_32k   seq 32768  global_batch 128   lowers serve_step (1 token,
                                               KV cache of seq length)
    long_500k    seq 524288 global_batch 1     serve_step; SSM/hybrid only —
                                               dense-attention archs skip
                                               (DESIGN.md S4)
"""

from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

from repro.models.lm import LMConfig, make_lm_model
from repro.models.ctr import CTRModelSpec


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq: int
    batch: int
    kind: str          # train | prefill | decode


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

_ARCH_MODULES = {
    "granite-8b": "granite_8b",
    "smollm-360m": "smollm_360m",
    "llama3-8b": "llama3_8b",
    "qwen3-4b": "qwen3_4b",
    "whisper-small": "whisper_small",
    "rwkv6-7b": "rwkv6_7b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "pixtral-12b": "pixtral_12b",
    "zamba2-1.2b": "zamba2_12b",
}

ARCH_NAMES = tuple(_ARCH_MODULES)


def get_config(name: str) -> LMConfig:
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


def get_source(name: str) -> str:
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.SOURCE


def applicable_shapes(name: str) -> dict[str, str]:
    """shape -> "run" or a skip reason (the 40-cell grid bookkeeping)."""
    cfg = get_config(name)
    out = {}
    for s in SHAPES:
        if s == "long_500k" and cfg.attention == "full":
            out[s] = ("SKIP: pure full-attention arch - 524k dense KV "
                      "decode reserved for sub-quadratic archs per "
                      "assignment (DESIGN.md S4)")
        else:
            out[s] = "run"
    return out


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(name: str, shape: str) -> dict:
    """Step-function inputs for (arch, shape): tokens/frontend stubs, and -
    for decode cells - the KV/state cache structs (obtained via eval_shape
    on ``init_cache``, so they exactly match the model)."""
    cfg = get_config(name)
    cell = SHAPES[shape]
    gb, s = cell.batch, cell.seq
    d = cfg.d_model
    tok = "int32"

    if cfg.family == "encdec":
        if cell.kind in ("train", "prefill"):
            return {"tokens": _sds((gb, s), tok),
                    "frames": _sds((gb, s, d), cfg.dtype)}
        # decode: one token + self-cache of length s + cross memory cache
        model = make_lm_model(cfg)
        cache = jax.eval_shape(lambda: model.init_cache(gb, s, s))
        return {"tokens": _sds((gb, 1), tok), "cache": cache}

    if cfg.family == "vlm":
        s_img = s // 4
        s_txt = s - s_img
        if cell.kind in ("train", "prefill"):
            return {"tokens": _sds((gb, s_txt), tok),
                    "patch_embeds": _sds((gb, s_img, d), cfg.dtype)}
        model = make_lm_model(cfg)
        cache = jax.eval_shape(lambda: model.init_cache(gb, s))
        return {"tokens": _sds((gb, 1), tok), "cache": cache}

    # decoder-only families (dense / moe / ssm / hybrid)
    if cell.kind in ("train", "prefill"):
        return {"tokens": _sds((gb, s), tok)}
    model = make_lm_model(cfg)
    if cfg.family == "ssm":
        cache = jax.eval_shape(lambda: model.init_cache(gb, 0))
    else:
        cache = jax.eval_shape(lambda: model.init_cache(gb, s))
    return {"tokens": _sds((gb, 1), tok), "cache": cache}


# ---------------------------------------------------------------------------
# paper CTR configs (SV-A: 4 models x {16, 32} x {256, 512, 1024})
# ---------------------------------------------------------------------------

def ctr_spec(model: str, dataset: str, embed_dim: int = 16,
             hidden: int = 256, max_field: int | None = None) -> CTRModelSpec:
    from repro.data.synthetic import AVAZU, CRITEO
    schema = {"avazu": AVAZU, "criteo": CRITEO}[dataset]
    if max_field:
        schema = schema.scaled(max_field)
    return CTRModelSpec(
        name=f"{model}_{dataset}_{embed_dim}_{hidden}",
        field_sizes=schema.field_sizes,
        embed_dim=embed_dim,
        hidden=(hidden,) * 3,
        cross_layers=3)
