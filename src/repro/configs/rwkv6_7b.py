"""rwkv6-7b - [arXiv:2404.05892; hf] Finch - data-dependent decay, attn-free"""

from repro.models.lm.config import LMConfig

SOURCE = "[arXiv:2404.05892; hf] Finch - data-dependent decay, attn-free"

CONFIG = LMConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,             # wkv heads = d_model / ssm_head_dim
    n_kv_heads=64,
    d_ff=14336,
    vocab=65536,
    ssm_head_dim=64,
    attention="none",
)
