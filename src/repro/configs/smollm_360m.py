"""smollm-360m - [hf:HuggingFaceTB/SmolLM-135M; hf] dense llama-arch small"""

from repro.models.lm.config import LMConfig

SOURCE = "[hf:HuggingFaceTB/SmolLM-135M; hf] dense llama-arch small"

CONFIG = LMConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab=49152,
)
