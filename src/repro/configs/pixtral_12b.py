"""pixtral-12b - [hf:mistralai/Pixtral-12B-2409; unverified] pixtral-ViT (stub) + mistral-nemo backbone"""

from repro.models.lm.config import LMConfig

SOURCE = "[hf:mistralai/Pixtral-12B-2409; unverified] pixtral-ViT (stub) + mistral-nemo backbone"

CONFIG = LMConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=131072,
    head_dim=128,
    patch_frontend=True,
    rope_theta=1_000_000.0,
)
