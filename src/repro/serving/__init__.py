"""Serving substrate: plan-cached batched CTR engine + LM generation.

CTR flow:  ``compile_plan`` (repro.core.plan) → ``InferencePlan`` →
``InferenceEngine`` (plan cache + pluggable batching policy).
"""

from .batching import (BatchDecision, BatchPolicy, BucketedBatch, FixedBatch,
                       TimeoutBatch)
from .engine import CTRServingEngine, EngineStats, InferenceEngine, ServeStats
from .generate import generate

__all__ = [
    "InferenceEngine",
    "EngineStats",
    "BatchPolicy",
    "BatchDecision",
    "FixedBatch",
    "BucketedBatch",
    "TimeoutBatch",
    "CTRServingEngine",
    "ServeStats",
    "generate",
]
