"""Serving substrate: plan-cached batched CTR engine + async runtime + LM
generation.

CTR flow:  ``compile_plan`` (repro.core.plan) → ``InferencePlan`` →
``InferenceEngine`` (plan cache + pluggable batching policy + futures-based
async intake) → ``ServingRuntime`` (multi-model router, one worker per
engine, shared admission cadence).
"""

from .batching import (BatchDecision, BatchPolicy, BucketedBatch, FixedBatch,
                       TimeoutBatch)
from .engine import (EngineStats, InferenceEngine, QueueFullError,
                     RequestFuture)
from .runtime import RuntimeStats, ServingRuntime
from .generate import generate

__all__ = [
    "InferenceEngine",
    "EngineStats",
    "RequestFuture",
    "QueueFullError",
    "ServingRuntime",
    "RuntimeStats",
    "BatchPolicy",
    "BatchDecision",
    "FixedBatch",
    "BucketedBatch",
    "TimeoutBatch",
    "generate",
]
