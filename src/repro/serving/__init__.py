"""Serving substrate: batched CTR engine + LM generation driver."""

from .engine import CTRServingEngine, ServeStats
from .generate import generate

__all__ = ["CTRServingEngine", "ServeStats", "generate"]
