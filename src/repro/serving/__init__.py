"""Serving substrate: plan-cached batched CTR engine + async runtime + LM
generation.

CTR flow:  ``compile_plan`` (repro.core.plan) → ``InferencePlan`` →
``InferenceEngine`` (plan cache + pluggable batching policy + futures-based
async intake) → ``ServingRuntime`` (multi-model router, shared admission
cadence) draining through a ``DeviceScheduler`` (one shared worker pool
serving every hosted engine least-SLO-slack-first; per-engine worker
threads remain as a compat mode). Online model updates stream in through
``repro.serving.updates`` (``DeltaSource``/``DeltaBuffer``/
``SyntheticTrainer``) and land via ``push_update``'s versioned
double-buffered publish — serving never pauses, plans never recompile.
"""

from .batching import (BatchDecision, BatchPolicy, BucketedBatch, FixedBatch,
                       TimeoutBatch)
from .engine import (EngineStats, InferenceEngine, QueueFullError,
                     ReadyBatch, RequestFuture)
from .runtime import RuntimeStats, ServingRuntime
from .scheduler import DeviceScheduler
from .updates import DeltaBuffer, DeltaSource, SyntheticTrainer
from .generate import generate

__all__ = [
    "InferenceEngine",
    "EngineStats",
    "RequestFuture",
    "ReadyBatch",
    "QueueFullError",
    "ServingRuntime",
    "RuntimeStats",
    "DeviceScheduler",
    "BatchPolicy",
    "BatchDecision",
    "FixedBatch",
    "BucketedBatch",
    "TimeoutBatch",
    "DeltaSource",
    "DeltaBuffer",
    "SyntheticTrainer",
    "generate",
]
