"""ServingRuntime — one async intake over many named engines.

Production CTR serving rarely hosts a single model: ranking and
pre-ranking models (e.g. ``deepfm`` + ``dcnv2``) sit behind one RPC
surface, each with its own plan cache, batching policy, and embedding
tier. ``ServingRuntime`` is that router over ``InferenceEngine``s:

    rt = ServingRuntime()
    rt.add_model("deepfm", deepfm, p1, policy=TimeoutBatch())
    rt.add_model("dcnv2", dcnv2, p2, store=CachedStore(...))
    rt.start()                       # shared pool drains every engine
    fut = rt.submit("deepfm", row)   # routed by model name
    fut.result()
    rt.stats().p99_ms                # aggregated across engines
    rt.stop()

The runtime owns

* **per-model routing**: ``submit``/``predict`` dispatch on the model
  name; unknown names fail fast with the hosted set in the message;
* **lifecycle fan-out**: ``start``/``stop``/``warmup``/``flush`` reach
  every engine. By default ``start()`` attaches every engine to one
  shared :class:`~repro.serving.DeviceScheduler` — ``pool_size``
  threads drain *all* queues least-SLO-slack-first, so hosting N models
  costs a constant thread count and a starved model's ``TimeoutBatch``
  deadline outranks a busy model's full buckets
  (``scheduler="per-engine"`` keeps the old worker-thread-per-engine
  mode; scores are bit-exact either way);
* **shared admission cadence**: with ``refresh_every=N`` the runtime
  counts *total* submitted traffic across models and refreshes every
  refreshable embedding store each time N more requests arrived — one
  HugeCTR-style refresh clock for the whole deployment instead of one
  per engine. Refreshes are double-buffered tensor swaps, so they never
  recompile any engine's plans;
* **online model updates**: ``push_update(model, row_ids, new_rows)``
  routes trainer deltas to the named engine's versioned publish, and
  ``attach_delta_stream`` + ``delta_every=N`` drains a
  :class:`~repro.serving.updates.DeltaSource` on the same shared
  admission clock (see ``docs/operations.md`` for staleness tuning);
* **aggregated stats**: :func:`ServingRuntime.stats` merges the
  per-engine counters into one :class:`RuntimeStats` snapshot (totals +
  merged latency percentiles + per-model ``EngineStats``).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Sequence

import numpy as np

from .engine import (AGGREGATED_COUNTERS, EngineStats, InferenceEngine,
                     RequestFuture)
from .scheduler import DeviceScheduler

__all__ = ["ServingRuntime", "RuntimeStats"]


@dataclasses.dataclass(frozen=True)
class RuntimeStats:
    """Point-in-time aggregate over every hosted engine.

    ``p50_ms``/``p99_ms`` are computed over the *union* of the engines'
    rolling latency windows (recent samples, same caveat as
    ``EngineStats``). ``per_model`` holds a *snapshot* of each engine's
    stats, taken under that engine's lock (``EngineStats.snapshot``) —
    drill-down counters are consistent and never mutate under the
    reader; re-call :meth:`ServingRuntime.stats` for fresh numbers.
    ``device_time_share`` sums the per-engine shares, so it reads ~1.0
    when a shared scheduler has dispatched anything and 0.0 in
    per-engine-worker mode. Every counter named in
    ``engine.AGGREGATED_COUNTERS`` is a field here — :meth:`stats` sums
    them generically, and the import-time check below keeps the two
    definitions from drifting.

    Online-update staleness: ``emb_delta_pushes``/``emb_delta_rows`` and
    ``rows_behind`` sum across engines, while ``emb_version`` and
    ``seconds_behind`` take the **max** — versions are per-engine
    sequences (two A/B engines deliberately sit at different versions),
    so the aggregate answers "how fresh is the deployment's most-updated
    set / how stale is the worst engine", and ``per_model`` drills into
    each engine's own version and gauges.
    """
    n_models: int
    n_requests: int
    n_batches: int
    n_rejected: int
    queue_depth: int
    n_worker_errors: int
    p50_ms: float
    p99_ms: float
    cache_hits: int
    cache_misses: int
    emb_cache_refreshes: int
    emb_staged_rows: int
    emb_prefetched_rows: int
    emb_h2d_bytes: int
    emb_staging_overflows: int
    emb_gather_bytes: int
    emb_quant_rows: int
    emb_quant_bytes_saved: int
    emb_version: int
    emb_delta_pushes: int
    emb_delta_rows: int
    rows_behind: int
    seconds_behind: float
    mlp_quant_matmuls: int
    mlp_quant_weight_bytes: int
    mlp_quant_weight_bytes_saved: int
    sched_dispatches: int
    sched_preempted_slack_ms: float
    device_time_share: float
    per_model: dict[str, EngineStats]


_missing = [name for name in AGGREGATED_COUNTERS
            if name not in RuntimeStats.__dataclass_fields__]
assert not _missing, (
    f"RuntimeStats lacks fields for AGGREGATED_COUNTERS: {_missing}")
del _missing


class ServingRuntime:
    """Multi-model router: named ``InferenceEngine``s behind one intake.

    Args:
        refresh_every: shared admission cadence — refresh every
            refreshable store once per N submitted requests *across all
            models* (``None`` disables; engines may still run their own
            per-engine ``refresh_every``).
        mesh: shared device mesh — the default for every
            :meth:`add_model` that doesn't pass its own ``mesh=``. Each
            hosted engine then serves multi-chip: params placed up front,
            batches sharded over the data axis, and the shared admission
            refreshes republish store tensors placed to the plans'
            shardings (never unplaced host arrays).
        scheduler: how :meth:`start` drains the hosted queues.
            ``"shared"`` (default): one :class:`DeviceScheduler` —
            ``pool_size`` threads serve every engine least-slack-first
            (thread count stays constant as models scale). A
            ``DeviceScheduler`` instance uses that scheduler (e.g. one
            pool shared across several runtimes on one device).
            ``"per-engine"``: the pre-scheduler compat mode, one worker
            thread per engine.
        pool_size: worker threads for the shared scheduler (ignored in
            ``"per-engine"`` mode or when a scheduler instance is
            passed).
        delta_every: online-update cadence — pull every attached delta
            stream (:meth:`attach_delta_stream`) once per N submitted
            requests across models, applying pending trainer pushes in a
            background thread off the intake hot path (same pattern as
            the shared admission refresh). Deltas land through each
            engine's versioned double-buffered publish, so cadence
            trades staleness (``rows_behind``/``seconds_behind``)
            against host-side scatter work only — never recompiles.
            ``None`` disables; :meth:`pull_updates`/:meth:`push_update`
            remain the manual surface.
    """

    def __init__(self, *, refresh_every: int | None = None, mesh=None,
                 scheduler: str | DeviceScheduler = "shared",
                 pool_size: int = 2, delta_every: int | None = None):
        self._engines: dict[str, InferenceEngine] = {}
        self.refresh_every = refresh_every
        self.mesh = mesh
        if isinstance(scheduler, DeviceScheduler):
            self.scheduler_mode = "shared"
            self._scheduler: DeviceScheduler | None = scheduler
        elif scheduler in ("shared", "per-engine"):
            self.scheduler_mode = scheduler
            self._scheduler = None
        else:
            raise ValueError(f"scheduler must be 'shared', 'per-engine' or "
                             f"a DeviceScheduler, got {scheduler!r}")
        self.pool_size = pool_size
        self.delta_every = delta_every
        self._submitted = 0
        self._refreshing = False
        self._refresh_thread: threading.Thread | None = None
        self._delta_pulling = False
        self._delta_thread: threading.Thread | None = None
        self._admission_lock = threading.Lock()

    # -- registry ------------------------------------------------------------
    def add_engine(self, name: str, engine: InferenceEngine
                   ) -> InferenceEngine:
        """Host an existing engine under ``name``."""
        if name in self._engines:
            raise ValueError(f"model {name!r} already registered")
        self._engines[name] = engine
        return engine

    def add_model(self, name: str, model, params,
                  **engine_kwargs) -> InferenceEngine:
        """Build and host an ``InferenceEngine`` for ``model`` — kwargs go
        straight to :class:`InferenceEngine` (policy, store, level, ...);
        the runtime's shared ``mesh`` applies unless overridden here."""
        engine_kwargs.setdefault("mesh", self.mesh)
        return self.add_engine(name,
                               InferenceEngine(model, params,
                                               **engine_kwargs))

    def engine(self, name: str) -> InferenceEngine:
        try:
            return self._engines[name]
        except KeyError:
            raise KeyError(f"no model {name!r}; hosting "
                           f"{sorted(self._engines)}") from None

    @property
    def models(self) -> tuple[str, ...]:
        return tuple(self._engines)

    # -- lifecycle -----------------------------------------------------------
    def warmup(self) -> None:
        for eng in self._engines.values():
            eng.warmup()

    @property
    def scheduler(self) -> DeviceScheduler | None:
        """The shared scheduler (None before ``start()`` or in
        per-engine mode)."""
        return self._scheduler

    def start(self) -> "ServingRuntime":
        """Start draining. Default (``scheduler="shared"``): attach every
        hosted engine to one :class:`DeviceScheduler` and start its
        ``pool_size``-thread pool — constant thread count however many
        models are hosted. Per-engine mode: one worker thread per engine
        (the pre-scheduler behaviour). Idempotent; engines added after a
        ``start()`` are picked up by calling it again."""
        if self.scheduler_mode == "shared":
            if self._scheduler is None:
                self._scheduler = DeviceScheduler(pool_size=self.pool_size)
            for name, eng in self._engines.items():
                self._scheduler.attach(name, eng)
            self._scheduler.start()
        else:
            for eng in self._engines.values():
                eng.start()
        return self

    def stop(self, flush: bool = True) -> None:
        """Stop the shared pool and/or every worker; with ``flush``
        (default) force-drain the leftover queues so no future stays
        unresolved. Joins any in-flight shared-admission refresh or
        delta pull. Every engine is stopped even if one raises; the
        first swallowed background-drain error
        (``EngineStats.n_worker_errors``) is re-raised at the end."""
        if self._scheduler is not None:
            self._scheduler.stop()
        errors: list[BaseException] = []
        for eng in self._engines.values():
            try:
                eng.stop(flush=flush)
            except Exception as exc:        # surface after stopping the rest
                errors.append(exc)
        with self._admission_lock:
            t, self._refresh_thread = self._refresh_thread, None
            d, self._delta_thread = self._delta_thread, None
        for bg in (t, d):
            if bg is not None and bg.is_alive():
                bg.join()
        if errors:
            raise errors[0]

    # -- intake --------------------------------------------------------------
    def submit(self, model: str, ids_row: np.ndarray) -> RequestFuture:
        """Route one request to ``model``'s engine; returns its future."""
        fut = self.engine(model).submit(ids_row)
        self._count_and_maybe_refresh(1)
        return fut

    def submit_many(self, model: str, rows: Sequence[np.ndarray]
                    ) -> list[RequestFuture]:
        futs = self.engine(model).submit_many(rows)
        self._count_and_maybe_refresh(len(futs))
        return futs

    def predict(self, model: str, ids) -> np.ndarray:
        """One-shot scores through ``model``'s engine (bypasses queues)."""
        return self.engine(model).predict(ids)

    def flush(self) -> dict[str, np.ndarray]:
        """Force-drain every engine; per-model scores in submit order."""
        return {name: eng.flush() for name, eng in self._engines.items()}

    # -- shared admission ----------------------------------------------------
    def _count_and_maybe_refresh(self, n: int) -> None:
        if not self.refresh_every and not self.delta_every:
            return
        with self._admission_lock:
            before = self._submitted
            self._submitted += n
            if self.delta_every:
                delta_crossed = (self._submitted // self.delta_every
                                 > before // self.delta_every)
                if delta_crossed and not self._delta_pulling:
                    # same off-hot-path rules as the refresh thread below:
                    # non-daemon, registered under the lock, joined in
                    # stop(). Deltas publish through each engine's
                    # versioned double-buffered swap — a short lag between
                    # crossing and publish only shows up as staleness.
                    self._delta_pulling = True
                    d = threading.Thread(target=self._pull_in_background,
                                         name="runtime-delta-pull")
                    self._delta_thread = d
                    d.start()
            if not self.refresh_every:
                return
            crossed = (self._submitted // self.refresh_every
                       > before // self.refresh_every)
            if crossed and not self._refreshing:
                # off the intake hot path: the boundary-crossing submit
                # must not pay the multi-store rebuild (or wait on drain
                # locks) — refreshes are double-buffered swaps, so a short
                # lag between crossing and publish is harmless. Non-daemon
                # (and joined in stop()): a daemon thread killed
                # mid-device-upload at interpreter exit aborts the
                # process. Registered under the lock so stop() can never
                # miss an in-flight refresh.
                self._refreshing = True
                t = threading.Thread(target=self._refresh_in_background,
                                     name="runtime-admission-refresh")
                self._refresh_thread = t
                t.start()

    def _refresh_in_background(self) -> None:
        try:
            self.refresh_all()
        finally:
            with self._admission_lock:
                self._refreshing = False

    def refresh_all(self) -> int:
        """Refresh every refreshable embedding store (double-buffered swap
        — no engine loses a compiled plan). Returns how many refreshed."""
        n = 0
        for eng in self._engines.values():
            store = eng.store
            if store is not None and store.refreshable:
                eng.refresh_cache()
                n += 1
        return n

    # -- online model updates ------------------------------------------------
    def push_update(self, model: str, row_ids, new_rows) -> int:
        """Apply one delta batch to ``model``'s engine (see
        :meth:`InferenceEngine.push_update`): the store scatters the new
        rows into backing + cache (+ staging), the engine publishes the
        fresh subtree in one swap and stamps the next ``emb_version`` —
        in-flight plans keep serving throughout, nothing recompiles.
        Returns rows applied (after dedupe)."""
        return self.engine(model).push_update(row_ids, new_rows)

    def attach_delta_stream(self, model: str, source) -> None:
        """Attach a :class:`~repro.serving.updates.DeltaSource` to
        ``model``'s engine. Drained by :meth:`pull_updates` or, with
        ``delta_every=N``, automatically once per N submitted requests;
        its backlog feeds the engine's ``rows_behind`` /
        ``seconds_behind`` gauges either way."""
        self.engine(model).attach_delta_source(source)

    def pull_updates(self, max_batches: int | None = None) -> int:
        """Drain every attached delta stream now (up to ``max_batches``
        per engine); returns total rows applied across models."""
        return sum(eng.pull_updates(max_batches=max_batches)
                   for eng in self._engines.values())

    def _pull_in_background(self) -> None:
        try:
            self.pull_updates()
        finally:
            with self._admission_lock:
                self._delta_pulling = False

    # -- stats ---------------------------------------------------------------
    def stats(self) -> RuntimeStats:
        """Aggregate snapshot across engines (see :class:`RuntimeStats`)."""
        lat: list[float] = []
        tot = {name: 0 for name in AGGREGATED_COUNTERS}
        # max-aggregated gauges (see the RuntimeStats docstring): summing
        # per-engine version sequences or queue ages is meaningless.
        emb_version = 0
        seconds_behind = 0.0
        for eng in self._engines.values():
            eng.poll_staleness()       # gauges reflect the backlog *now*
            st = eng.stats
            with st.lock:
                lat.extend(st.latency_ms)
                for name in AGGREGATED_COUNTERS:
                    tot[name] += getattr(st, name)
                emb_version = max(emb_version, st.emb_version)
                seconds_behind = max(seconds_behind, st.seconds_behind)
        return RuntimeStats(
            n_models=len(self._engines),
            p50_ms=float(np.percentile(lat, 50)) if lat else 0.0,
            p99_ms=float(np.percentile(lat, 99)) if lat else 0.0,
            emb_version=emb_version,
            seconds_behind=seconds_behind,
            per_model={n: e.stats.snapshot()
                       for n, e in self._engines.items()},
            **tot)
