"""LM generation driver: prefill + greedy/temperature decode over any
architecture exposing (init_cache, prefill, decode_step)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["generate"]


def generate(model, params, tokens, *, max_new: int = 32,
             temperature: float = 0.0, key=None, **prefill_kwargs):
    """tokens (b, s) -> (b, s + max_new). Greedy when temperature == 0."""
    b, s = tokens.shape
    cfg = model.cfg
    if cfg.family == "encdec":
        cache = model.init_cache(b, s + max_new,
                                 prefill_kwargs["frames"].shape[1])
        logits, cache = model.prefill(params, tokens,
                                      prefill_kwargs["frames"], cache)
    elif cfg.family == "ssm":
        cache = model.init_cache(b, 0)
        logits, cache = model.prefill(params, tokens, cache)
    elif cfg.family == "vlm" and "patch_embeds" in prefill_kwargs:
        s_img = prefill_kwargs["patch_embeds"].shape[1]
        cache = model.init_cache(b, s_img + s + max_new)
        logits, cache = model.prefill(
            params, tokens, cache,
            patch_embeds=prefill_kwargs["patch_embeds"])
    else:
        cache = model.init_cache(b, s + max_new)
        logits, cache = model.prefill(params, tokens, cache)

    if key is None:
        key = jax.random.PRNGKey(0)
    decode = jax.jit(model.decode_step)
    out = [tokens]
    for i in range(max_new):
        if temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        nxt = nxt[:, None].astype(tokens.dtype)
        out.append(nxt)
        if i < max_new - 1:
            logits, cache = decode(params, nxt, cache)
    return jnp.concatenate(out, axis=1)
