"""Pluggable batching policies for the InferenceEngine.

A policy decides, given the queue depth and the age of the oldest waiting
request, *how many* requests to dequeue and *which padded batch shape*
("bucket") to run them through — one compiled :class:`~repro.core.plan.
InferencePlan` exists per bucket, so the set of buckets a policy can emit
is exactly the engine's plan-cache working set.

  FixedBatch     the classic pad-to-N loop (the old engine's behaviour).
  BucketedBatch  a ladder of padded shapes: full buckets drain largest-
                 first, the remainder pads into the smallest bucket that
                 covers it — bounding padding waste to < smallest bucket
                 per drain instead of < N.
  TimeoutBatch   latency-SLO wrapper: full buckets go immediately, partial
                 batches only once the oldest request has waited past the
                 deadline (or on an explicit ``flush``).
"""

from __future__ import annotations

import dataclasses

__all__ = ["BatchDecision", "BatchPolicy", "FixedBatch", "BucketedBatch",
           "TimeoutBatch"]


@dataclasses.dataclass(frozen=True)
class BatchDecision:
    """Dequeue ``take`` requests and run them padded to ``bucket`` rows."""
    take: int
    bucket: int

    def __post_init__(self):
        if not 0 < self.take <= self.bucket:
            raise ValueError(f"need 0 < take <= bucket, got {self}")


class BatchPolicy:
    """Interface: ``decide`` may be called repeatedly per drain — return
    None to stop draining (requests stay queued)."""

    @property
    def buckets(self) -> tuple[int, ...]:
        """Every batch shape this policy can emit (the plan-cache working
        set; engines warm these)."""
        raise NotImplementedError

    @property
    def partial_hold_ms(self) -> float | None:
        """How long a partial batch may wait for more arrivals before it
        becomes *due* — the deadline behind the engine's ``next_ready``
        readiness view (SLO slack = hold − oldest wait). ``None`` means
        the policy has no deadline of its own and the engine's default
        grace (a few worker ticks) applies; ``TimeoutBatch`` overrides
        this with its explicit ``max_wait_ms`` SLO."""
        return None

    def decide(self, pending: int, oldest_wait_ms: float, *,
               allow_partial: bool) -> BatchDecision | None:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class FixedBatch(BatchPolicy):
    """Always pad to one fixed shape (the legacy pad-to-256 loop)."""
    size: int = 256

    def __post_init__(self):
        if self.size < 1:
            raise ValueError(f"size must be >= 1, got {self.size}")

    @property
    def buckets(self) -> tuple[int, ...]:
        return (self.size,)

    def decide(self, pending: int, oldest_wait_ms: float, *,
               allow_partial: bool) -> BatchDecision | None:
        if pending >= self.size:
            return BatchDecision(self.size, self.size)
        if pending > 0 and allow_partial:
            return BatchDecision(pending, self.size)
        return None


@dataclasses.dataclass(frozen=True)
class BucketedBatch(BatchPolicy):
    """A ladder of padded batch shapes with one cached plan per bucket.

    Full buckets drain largest-first; a remainder smaller than the smallest
    bucket pads into it only when partial batches are allowed.
    """
    ladder: tuple[int, ...] = (32, 64, 128, 256)

    def __post_init__(self):
        ladder = tuple(sorted(set(int(b) for b in self.ladder)))
        if not ladder or ladder[0] < 1:
            raise ValueError(f"ladder must hold sizes >= 1, got {self.ladder}")
        object.__setattr__(self, "ladder", ladder)

    @property
    def buckets(self) -> tuple[int, ...]:
        return self.ladder

    def decide(self, pending: int, oldest_wait_ms: float, *,
               allow_partial: bool) -> BatchDecision | None:
        if pending <= 0:
            return None
        full = [b for b in self.ladder if b <= pending]
        if full:
            return BatchDecision(full[-1], full[-1])
        # pending < smallest bucket: partial into the smallest shape
        if allow_partial:
            return BatchDecision(pending, self.ladder[0])
        return None


@dataclasses.dataclass(frozen=True)
class TimeoutBatch(BatchPolicy):
    """Latency-SLO draining: run full buckets of ``inner`` immediately, but
    hold partial batches until the oldest request has waited
    ``max_wait_ms`` (engines force-drain by passing an infinite wait)."""
    inner: BatchPolicy = dataclasses.field(default_factory=BucketedBatch)
    max_wait_ms: float = 5.0

    @property
    def buckets(self) -> tuple[int, ...]:
        return self.inner.buckets

    @property
    def partial_hold_ms(self) -> float | None:
        return self.max_wait_ms

    def decide(self, pending: int, oldest_wait_ms: float, *,
               allow_partial: bool) -> BatchDecision | None:
        d = self.inner.decide(pending, oldest_wait_ms, allow_partial=False)
        if d is not None:
            return d
        if allow_partial and oldest_wait_ms >= self.max_wait_ms:
            return self.inner.decide(pending, oldest_wait_ms,
                                     allow_partial=True)
        return None
