"""Batched CTR inference engine — the paper's deployment surface.

Requests (one sample each: per-field id vectors) are queued and served in
fixed-size batches through a DualParallelExecutor at any Fig.-8 level;
under-full batches are padded (padding rows sliced off the response).
Latency accounting distinguishes queueing from compute — the numbers the
paper's Fig. 7 measures.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import DualParallelExecutor
from repro.models.ctr.common import CTRModel

__all__ = ["CTRServingEngine", "ServeStats"]


@dataclasses.dataclass
class ServeStats:
    n_requests: int = 0
    n_batches: int = 0
    compute_ms_total: float = 0.0
    latency_ms: list = dataclasses.field(default_factory=list)

    @property
    def p50_ms(self) -> float:
        return float(np.percentile(self.latency_ms, 50)) if self.latency_ms else 0.0

    @property
    def p99_ms(self) -> float:
        return float(np.percentile(self.latency_ms, 99)) if self.latency_ms else 0.0


class CTRServingEngine:
    def __init__(self, model: CTRModel, params: dict, *, batch_size: int = 256,
                 level: str = "dual", branch_order: str = "longer_first"):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.executor = DualParallelExecutor(model.build_graph, level=level,
                                             branch_order=branch_order)
        self._step = self.executor.build(params)
        self._queue: deque = deque()
        self.stats = ServeStats()

    def warmup(self) -> None:
        ids = jnp.zeros((self.batch_size, self.model.spec.k), dtype=jnp.int32)
        jax.block_until_ready(self._step({"ids": ids}))

    def submit(self, ids_row: np.ndarray) -> None:
        self._queue.append((time.perf_counter(), np.asarray(ids_row)))

    def pending(self) -> int:
        return len(self._queue)

    def serve_pending(self, allow_partial: bool = True) -> np.ndarray:
        """Drain the queue in batches; returns all scores in submit order."""
        out: list[np.ndarray] = []
        while self._queue:
            if len(self._queue) < self.batch_size and not allow_partial:
                break
            take = min(self.batch_size, len(self._queue))
            items = [self._queue.popleft() for _ in range(take)]
            t_submit = [it[0] for it in items]
            rows = np.stack([it[1] for it in items])
            if take < self.batch_size:                 # pad to fixed shape
                pad = np.zeros((self.batch_size - take, rows.shape[1]),
                               dtype=rows.dtype)
                rows = np.concatenate([rows, pad])
            t0 = time.perf_counter()
            logits = self._step({"ids": jnp.asarray(rows, dtype=jnp.int32)})
            scores = np.asarray(jax.nn.sigmoid(
                jnp.asarray(logits).reshape(-1)))[:take]
            t1 = time.perf_counter()
            out.append(scores)
            self.stats.n_requests += take
            self.stats.n_batches += 1
            self.stats.compute_ms_total += (t1 - t0) * 1e3
            self.stats.latency_ms.extend(
                (t1 - ts) * 1e3 for ts in t_submit)
        return np.concatenate(out) if out else np.empty((0,))
