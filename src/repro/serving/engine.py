"""InferenceEngine — the single serving surface over compiled plans.

The deployment story (paper Fig. 7) as three layers:

    plan  = compile_plan(model, params, "dual", 256)   # repro.core.plan
    eng   = InferenceEngine(model, params, policy=BucketedBatch())
    fut   = eng.submit(row); fut.result()              # async intake
    eng.submit(row); scores = eng.serve_pending()      # or sync drain

The engine owns

* a **plan cache** keyed by ``(model, level, batch_bucket)`` — each batching
  bucket compiles once and is reused for every later batch of that shape
  (hit/miss counts are in ``stats``);
* a **batching policy** (``repro.serving.batching``) deciding how queued
  single-sample requests group into padded device batches;
* a **request queue of futures**: ``submit`` returns a
  :class:`RequestFuture` that resolves (score + latency) when its batch is
  served — either by a caller-driven drain (``serve_pending``/``flush``)
  or by the **background worker thread** (``start()``/``stop()``), which
  drains the queue through the policy on its own so latency-SLO policies
  like ``TimeoutBatch`` fire without any caller polling (PCDF's
  full-link-asynchronous serving loop);
* **latency accounting** separating queueing from compute (bounded rolling
  p50/p99 window — see ``EngineStats``; all counters behind one lock so
  the worker and callers never race), plus per-bucket compile counts and
  padding-waste fractions so benchmarks can quantify the bucketing win;
* an optional **embedding store** tier (``store=CachedStore(...)``): the
  engine feeds served id traffic to the store's admission counters and
  rebuilds the hot-row cache on ``refresh_cache()`` (or every
  ``refresh_every`` batches). The store's tensors are *runtime inputs* of
  every compiled plan (``EmbeddingStore.runtime_keys``), so a refresh is
  a double-buffered tensor swap — build the new cache tensors on the
  side, publish them in one atomic reference swap — and the entire plan
  cache survives with zero recompiles (HugeCTR's online cache refresh
  over DPIFrame plans);
* the **staging pipeline** for out-of-HBM stores
  (``store=HostBackedStore(...)``, ``EmbeddingStore.needs_staging``):
  before each batch's compute the engine has the store resolve the
  batch's cache misses into the device staging buffer (``store.stage`` —
  published through the same runtime-tensor swap, zero recompiles), and
  while that batch computes it hints the *next* queued batch's ids to the
  store's async prefetch worker so the host-side gather runs off the
  critical path. A miss set too big for the staging buffer falls back to
  serving the batch in chunks through the same plan — slower, never
  wrong;
* **online model updates** (``push_update``/``pull_updates``): a live
  trainer's ``(row_id, new_row)`` delta stream lands through that same
  double-buffered publish — fresh store tiers built on the side, one
  atomic swap stamped with a monotonic ``emb_version`` — so parameter
  *values* change under live traffic with zero recompiles and no torn
  reads (hard-asserted), with staleness observable as
  ``stats.rows_behind``/``seconds_behind`` (HugeCTR's incremental-update
  pipeline over DPIFrame plans; sources live in ``serving/updates.py``).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
import threading
import time
from collections import deque
from typing import Callable, Sequence

import numpy as np
import jax

from repro.core.plan import (InferencePlan, PlanKey, compile_plan,
                             place_params, plan_key_for)
from repro.embedding import StagingOverflowError
from .batching import BatchPolicy, BucketedBatch

__all__ = ["InferenceEngine", "EngineStats", "RequestFuture", "ReadyBatch",
           "QueueFullError", "AGGREGATED_COUNTERS"]

#: StoreStats attribute -> the EngineStats counter mirroring it. This table
#: *is* the wiring: ``_mirror_store_stats`` copies by name under the stats
#: lock, so surfacing a new store counter means one entry here (plus the
#: EngineStats field), not another hand-written copy block.
_STORE_MIRROR = {
    "hits": "emb_cache_hits",
    "misses": "emb_cache_misses",
    "refreshes": "emb_cache_refreshes",
    "staged_rows": "emb_staged_rows",
    "prefetched_rows": "emb_prefetched_rows",
    "h2d_bytes": "emb_h2d_bytes",
    "staging_overflows": "emb_staging_overflows",
    "gather_bytes": "emb_gather_bytes",
    "quant_rows": "emb_quant_rows",
    "quant_bytes_saved": "emb_quant_bytes_saved",
}
# NOTE: StoreStats.delta_rows is deliberately NOT mirrored: two engines may
# share one store (A/B over a common backing), and a mirror would credit
# every engine with every push. ``push_update`` counts its own
# ``emb_delta_rows``, so per-engine and runtime totals stay exact.

#: ExecutorStats attribute -> the EngineStats counter accumulating it once
#: per *plan compile* (weight bytes are a property of the compiled plan,
#: not of served traffic); applied on every plan-cache miss.
_PLAN_MIRROR = {
    "mlp_quant_weight_bytes": "mlp_quant_weight_bytes",
    "mlp_quant_weight_bytes_saved": "mlp_quant_weight_bytes_saved",
}

#: Every additive EngineStats counter ``ServingRuntime.stats()`` rolls up
#: across engines — the engine's own totals plus the mirrored store/plan
#: counters above, so a counter added to either mirror table aggregates
#: into RuntimeStats without touching runtime.py (it still needs the
#: matching RuntimeStats field, which the dataclass asserts at import).
AGGREGATED_COUNTERS = (
    "n_requests", "n_batches", "n_rejected", "queue_depth",
    "n_worker_errors",
    "cache_hits", "cache_misses",
    "emb_cache_refreshes", "emb_staged_rows", "emb_prefetched_rows",
    "emb_h2d_bytes", "emb_staging_overflows", "emb_gather_bytes",
    "emb_quant_rows", "emb_quant_bytes_saved",
    "emb_delta_pushes", "emb_delta_rows", "rows_behind",
    "mlp_quant_matmuls", "mlp_quant_weight_bytes",
    "mlp_quant_weight_bytes_saved",
    "sched_dispatches", "sched_preempted_slack_ms", "device_time_share",
)
# emb_version and seconds_behind are aggregated by MAX, not sum — the
# runtime handles them as customs (a sum of versions means nothing).


@dataclasses.dataclass(frozen=True)
class ReadyBatch:
    """One engine's dispatch candidate, as seen by a device scheduler.

    ``slack_ms <= 0`` means the batch is due *now* (a full bucket, or a
    partial batch whose hold deadline has passed — ``-slack_ms`` is then
    how far past it already is); ``slack_ms > 0`` means a partial batch
    that becomes due in ``slack_ms`` (the scheduler's wake-up hint).
    ``partial`` tells the dispatcher whether serving it needs
    ``allow_partial`` — at dispatch time the engine re-decides against
    the *current* queue, so requests that arrived meanwhile coalesce into
    (possibly a larger bucket of) the same dispatch.
    """
    take: int
    bucket: int
    slack_ms: float
    partial: bool


class QueueFullError(RuntimeError):
    """``submit`` rejected a request because the engine's queue is at
    ``max_queue_depth`` (backpressure: a stalled device must surface as
    fast failures at the intake, not as an unbounded queue)."""


class RequestFuture:
    """Resolution handle for one submitted request.

    Resolves to the request's sigmoid score; ``latency_ms`` (submit →
    resolution, the same sample fed to the engine's rolling window) is set
    at resolution time. Futures resolve in submit order — within a batch
    and across batches — because a single drain loop serves the queue
    FIFO. Done-callbacks run on the resolving thread (the worker, for an
    engine with ``start()`` called).
    """

    __slots__ = ("_event", "_lock", "_score", "_exc", "_callbacks",
                 "t_submit", "latency_ms")

    def __init__(self):
        self._event = threading.Event()
        self._lock = threading.Lock()   # guards _callbacks vs resolution
        self._score: float | None = None
        self._exc: BaseException | None = None
        self._callbacks: list[Callable[[RequestFuture], None]] = []
        self.t_submit = time.perf_counter()
        self.latency_ms: float | None = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> float:
        """Block until resolved; returns the score (or re-raises the
        serving error that failed this request's batch)."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"request not served within {timeout}s")
        if self._exc is not None:
            raise self._exc
        return self._score

    def add_done_callback(self, fn: Callable[[RequestFuture], None]) -> None:
        """Run ``fn(self)`` on resolution (immediately if already done).
        Callback exceptions are swallowed (stdlib-Future semantics): one
        bad callback must never block other requests from resolving."""
        with self._lock:
            if not self._event.is_set():
                self._callbacks.append(fn)
                return
        self._run_callback(fn)

    def _run_callback(self, fn) -> None:
        try:
            fn(self)
        except Exception:
            pass

    def _finish(self) -> None:
        with self._lock:
            self._event.set()
            cbs, self._callbacks = self._callbacks, []
        for fn in cbs:
            self._run_callback(fn)

    def _resolve(self, score: float, latency_ms: float) -> None:
        self._score = score
        self.latency_ms = latency_ms
        self._finish()

    def _fail(self, exc: BaseException) -> None:
        self._exc = exc
        self._finish()


@dataclasses.dataclass
class EngineStats:
    """Serving counters: request/batch totals, queue depth, latency split,
    plan-cache behaviour, padding waste per bucket, and embedding-store
    cache health.

    **Thread safety**: every mutation (and every compound read) happens
    under ``lock`` — one re-entrant lock covering the counters *and* the
    rolling latency window, so the background worker, sync drains, and
    stat readers never interleave mid-update. ``p50_ms``/``p99_ms``
    snapshot the window under the lock.

    Latency accounting is a **bounded rolling window**: ``latency_ms``
    keeps only the most recent ``latency_window`` per-request samples
    (default 8192), so memory stays O(window) under sustained traffic.
    ``p50_ms``/``p99_ms`` are therefore *recent* percentiles — over the
    last ``latency_window`` served requests, not engine lifetime — which
    is what an SLO monitor wants anyway; lifetime totals remain exact in
    ``n_requests``/``compute_ms_total``.

    ``queue_depth`` is the number of submitted-but-unserved requests at
    the last queue transition (kept current by the engine); ``n_rejected``
    counts submits refused by the ``max_queue_depth`` backpressure bound
    (their futures fail with :class:`QueueFullError`).

    The ``emb_*`` counters mirror the engine's embedding store
    (``CachedStore``/``HostBackedStore``): row-lookup hits/misses against
    the current index map, cache rebuilds, and the fraction of observed
    traffic mass whose rows are currently cached (the fraction is a
    full-vocabulary scan, so it is refreshed at ``refresh_cache`` time,
    not per batch). The staging four (``emb_staged_rows`` — rows gathered
    host-side synchronously at serve time, ``emb_prefetched_rows`` — miss
    rows the async worker had already resolved, ``emb_h2d_bytes`` — host→
    device staging traffic, ``emb_staging_overflows`` — batches served via
    the chunked fallback) are live only for ``needs_staging`` stores. All
    zero for the default ``DenseStore``.

    Byte counters are *wire* bytes (dtype-aware): ``emb_gather_bytes``
    accounts observed gather traffic at the store's per-row wire cost
    (``4·d`` fp32, ``d + 4`` int8 + scale), and the quantization pair
    (``emb_quant_rows`` — rows quantized at init/adopt/refresh,
    ``emb_quant_bytes_saved`` — gather bytes the int8 representation
    avoided) is nonzero only for ``row_dtype="int8"`` stores.

    The online-update group tracks delta-stream freshness:
    ``emb_version`` is the monotonic version of the engine's published
    embedding tensor set — 0 at load, +1 per applied ``push_update``
    batch; the publish and the bump happen atomically under this lock,
    and ``InferenceEngine._runtime_env`` hard-asserts the sequence every
    compiled step observes never runs backwards. ``emb_delta_pushes`` /
    ``emb_delta_rows`` count applied batches and deduped rows (engine's
    own pushes only — a store shared A/B-style across engines is not
    double-counted). ``rows_behind``/``seconds_behind`` are staleness
    *gauges* refreshed from the attached :class:`~repro.serving.updates.
    DeltaSource` on every pull: delta rows queued but not yet applied,
    and the age of the oldest of them (both 0 when caught up or when no
    source is attached).

    The ``mlp_quant_*`` trio mirrors the quantized-*compute* half
    (``compute_dtype="int8"`` plans): ``mlp_quant_matmuls`` counts int8
    matmul dispatches across served batches, and the weight-byte pair
    accumulates once per compiled plan (int8 payload + per-channel scales,
    and the bytes saved vs the fp32 matrices). All zero for fp32 engines.

    ``n_worker_errors`` counts exceptions a background drain (the
    engine's own worker or a shared-pool dispatch) swallowed after
    failing that batch's futures; the last one is kept in
    ``engine.worker_error`` and re-raised by ``stop()``.

    The ``sched_*`` trio is live only when a :class:`~repro.serving.
    DeviceScheduler` serves this engine: ``sched_dispatches`` counts
    batches the shared pool dispatched here, ``sched_preempted_slack_ms``
    accumulates how many milliseconds past their SLO deadline this
    engine's due partial batches sat while the device worked other models
    (contention-burned slack — 0 means every deadline was picked up on
    time), and ``device_time_share`` is this engine's fraction of all
    device time the scheduler has dispatched (shares over one scheduler's
    engines sum to 1).
    """
    n_requests: int = 0
    n_batches: int = 0
    n_rejected: int = 0
    queue_depth: int = 0
    n_worker_errors: int = 0
    sched_dispatches: int = 0
    sched_preempted_slack_ms: float = 0.0
    device_time_share: float = 0.0
    compute_ms_total: float = 0.0
    latency_window: int = 8192
    latency_ms: deque = None
    cache_hits: int = 0
    cache_misses: int = 0
    compile_ms_per_bucket: dict = dataclasses.field(default_factory=dict)
    batches_per_bucket: dict = dataclasses.field(default_factory=dict)
    padded_rows_total: int = 0
    emb_cache_hits: int = 0
    emb_cache_misses: int = 0
    emb_cache_refreshes: int = 0
    emb_cached_traffic_fraction: float = 0.0
    emb_staged_rows: int = 0
    emb_prefetched_rows: int = 0
    emb_h2d_bytes: int = 0
    emb_staging_overflows: int = 0
    emb_gather_bytes: int = 0
    emb_quant_rows: int = 0
    emb_quant_bytes_saved: int = 0
    emb_version: int = 0
    emb_delta_pushes: int = 0
    emb_delta_rows: int = 0
    rows_behind: int = 0
    seconds_behind: float = 0.0
    mlp_quant_matmuls: int = 0
    mlp_quant_weight_bytes: int = 0
    mlp_quant_weight_bytes_saved: int = 0

    def __post_init__(self):
        self.latency_ms = deque(self.latency_ms or (),
                                maxlen=self.latency_window)
        self.lock = threading.RLock()

    def snapshot(self) -> "EngineStats":
        """Consistent point-in-time copy, taken under the lock: containers
        are copied, the new object has its own lock, and later engine
        activity never mutates it (what ``RuntimeStats.per_model`` hands
        out, so drill-down counters don't change under the reader)."""
        with self.lock:
            kw = {}
            for f in dataclasses.fields(self):
                v = getattr(self, f.name)
                if isinstance(v, deque):
                    v = tuple(v)
                elif isinstance(v, dict):
                    v = dict(v)
                kw[f.name] = v
        return EngineStats(**kw)

    @property
    def p50_ms(self) -> float:
        with self.lock:
            samples = list(self.latency_ms)
        return float(np.percentile(samples, 50)) if samples else 0.0

    @property
    def p99_ms(self) -> float:
        with self.lock:
            samples = list(self.latency_ms)
        return float(np.percentile(samples, 99)) if samples else 0.0

    @property
    def padding_waste(self) -> float:
        """Fraction of served device rows that were padding."""
        with self.lock:
            rows = self.n_requests + self.padded_rows_total
            return self.padded_rows_total / rows if rows else 0.0

    @property
    def emb_cache_hit_rate(self) -> float:
        """Row-lookup hit rate of the embedding store's hot cache."""
        with self.lock:
            n = self.emb_cache_hits + self.emb_cache_misses
            return self.emb_cache_hits / n if n else 0.0

    @property
    def emb_prefetch_hit_rate(self) -> float:
        """Fraction of staged miss rows the async prefetch worker resolved
        before the batch reached the serve path (1.0 = the host gather is
        entirely off the critical path)."""
        with self.lock:
            n = self.emb_staged_rows + self.emb_prefetched_rows
            return self.emb_prefetched_rows / n if n else 0.0


class InferenceEngine:
    """Batched CTR inference over a cache of compiled ``InferencePlan``s.

    Args:
        model: any CTR model (``spec`` + ``build_graph``).
        params: parameter pytree.
        level: Fig.-8 executor level for every plan this engine compiles.
        policy: batching policy; default ``BucketedBatch()``.
        branch_order: breadth-first head-branch choice (§V-H).
        mesh: optional device mesh — the engine places its live params on
            it up front (embedding tables row-sharded over the model axis,
            placement delegated to the model/store ``partition_spec``) and
            every plan it compiles shards per-call batches over the data
            axis. ``refresh_cache()`` republishes fresh store tensors
            *placed to the plan's shardings* (``EmbeddingStore.place``),
            so the double-buffered swap stays a true multi-chip refresh:
            no recompiles, no unplaced host arrays behind compiled steps.
        donate: donate input buffers to the compiled steps (level "dual"
            only; the eager levels ignore it). Runtime store tensors are
            never donated.
        compute_dtype: dense-branch compute dtype for every plan this
            engine compiles — ``"fp32"`` (default) or ``"int8"`` (fused
            quantized matmuls, see ``compile_plan``). Part of the plan
            cache key, so engines at different dtypes never share plans;
            refresh stays recompile-free either way (MLP weights quantize
            once at compile and are not runtime inputs).
        store: optional ``repro.embedding`` store (e.g. ``CachedStore``)
            to retrofit onto the model's main embedding table; ``params``
            are converted bit-exactly into the store's layout. The engine
            feeds every served id batch back to the store's admission
            counters and exposes hit-rate/refresh counters in ``stats``.
        refresh_every: rebuild the store's hot cache every N served
            batches (HugeCTR-style refresh interval). A refresh is a
            double-buffered tensor swap — compiled plans take the store
            tensors as runtime inputs and survive untouched — so N trades
            admission freshness against host-side rebuild work only.
            ``None`` = manual ``refresh_cache()`` only.
        max_queue_depth: optional backpressure bound — ``submit`` beyond
            this many queued-but-unserved requests *rejects*: the returned
            future fails with :class:`QueueFullError` instead of the queue
            growing without bound on a stalled device (``stats.n_rejected``
            counts rejections). ``None`` (default) never rejects.
        latency_window: size of the rolling latency window behind
            ``stats.p50_ms``/``p99_ms`` (see ``EngineStats``).
        worker_tick_ms: how long the background worker sleeps between
            drain attempts while the policy is holding requests back
            (e.g. a ``TimeoutBatch`` SLO window still open).
    """

    def __init__(self, model, params, *, level: str = "dual",
                 policy: BatchPolicy | None = None,
                 branch_order: str = "longer_first",
                 mesh: jax.sharding.Mesh | None = None,
                 donate: bool = False,
                 compute_dtype: str = "fp32",
                 store=None,
                 refresh_every: int | None = None,
                 max_queue_depth: int | None = None,
                 latency_window: int = 8192,
                 worker_tick_ms: float = 0.5):
        self.model = model
        if store is not None:
            params = model.use_store(store, params)
        if mesh is not None:
            # place the live params once: the runtime provider behind every
            # compiled plan reads self.params, so the tensors it hands out
            # must already carry the mesh placement (compile_plan's own
            # place_params is then a no-op re-put of placed arrays)
            params = place_params(model, params, mesh)
        self.params = params
        self.max_queue_depth = max_queue_depth
        self.level = level
        self.policy = policy if policy is not None else BucketedBatch()
        self.branch_order = branch_order
        self.mesh = mesh
        self.donate = donate
        self.compute_dtype = compute_dtype
        self.refresh_every = refresh_every
        self.worker_tick_ms = worker_tick_ms
        self._plans: dict[PlanKey, InferencePlan] = {}
        self._queue: deque = deque()
        # lock order (never reversed): _drain_lock -> _cv -> stats.lock.
        # _drain_lock serializes everything that touches host-side store
        # state (drains/observe/refresh) and is re-entrant so an
        # auto-refresh inside a drain doesn't self-deadlock.
        self._cv = threading.Condition(threading.Lock())
        self._drain_lock = threading.RLock()
        self._compile_lock = threading.Lock()
        self._worker: threading.Thread | None = None
        self._running = False
        self._scheduler = None        # set by DeviceScheduler.attach
        self._delta_source = None     # set by attach_delta_source
        # highest emb_version any compiled step has observed — the floor
        # the _runtime_env monotonicity hard-assert enforces
        self._version_floor = 0
        self.worker_error: BaseException | None = None
        self.stats = EngineStats(latency_window=latency_window)
        staging = self._staging_store
        if staging is not None and mesh is not None:
            # stage-time publishes must land mesh-placed like everything
            # else in self.params (refresh already goes through place())
            staging.bind_mesh(mesh)

    # -- embedding store -----------------------------------------------------
    @property
    def store(self):
        """The model's main embedding store (DenseStore unless swapped)."""
        coll = getattr(self.model, "embedding", None)
        return getattr(coll, "store", None)

    def _runtime_env(self) -> dict:
        """Current runtime store tensors for compiled plans — re-read on
        every step call, so one atomic ``self.params`` swap (a refresh or
        a delta publish) retargets every cached plan. Same duck-typing
        guard as ``compile_plan``: models without the store surface have
        none.

        The params read and the version read happen under the stats lock
        — the same lock ``push_update`` publishes under — so the pair is
        consistent, and the **version-monotonicity hard-assert** holds:
        the env a step binds always belongs to a version >= every version
        previously observed. A torn update (old tensors after a newer
        publish) would trip this immediately.
        """
        if not hasattr(self.model, "store_runtime_env"):
            return {}
        with self.stats.lock:
            v = self.stats.emb_version
            if v < self._version_floor:
                raise AssertionError(
                    f"embedding version ran backwards: step observed "
                    f"v{v} after v{self._version_floor} was already "
                    "served — torn/reordered publish")
            self._version_floor = v
            return self.model.store_runtime_env(self.params)

    def _observe_traffic(self, rows: np.ndarray) -> None:
        """Feed served ids to the store's admission counters and mirror
        the store's health into ``stats`` (host-side, outside jit). Only
        refreshable (cache-tiered) stores pay this — and the O(rows)
        cached-traffic scan is deferred to refresh time, not per batch."""
        coll = getattr(self.model, "embedding", None)
        if coll is None or not coll.store.refreshable:
            return
        coll.observe(rows)
        self._mirror_store_stats()

    def _mirror_store_stats(self) -> None:
        ss = self.store.stats
        st = self.stats
        with st.lock:
            for src, dst in _STORE_MIRROR.items():
                setattr(st, dst, getattr(ss, src))

    # -- staging (out-of-HBM stores) ----------------------------------------
    @property
    def _staging_store(self):
        """The embedding store when it needs per-batch staging, else None."""
        store = self.store
        if store is not None and getattr(store, "needs_staging", False):
            return store
        return None

    def _predict_staged(self, plan: InferencePlan, rows: np.ndarray
                        ) -> np.ndarray:
        """Run ``plan.predict`` with every embedding miss of ``rows``
        resolved first. Caller holds ``_drain_lock`` (staging republishes
        ``self.params`` and must not race a refresh).

        Fast path: one ``store.stage`` (mostly prefetch hits) + one
        predict. A :class:`StagingOverflowError` — the batch's distinct
        miss set exceeds the staging buffer — falls back to the
        synchronous chunked host gather: ``split_for_staging`` cuts the
        batch so every chunk's misses fit, and each chunk is staged and
        served through the *same* compiled plan (which pads each chunk to
        the bucket shape). Slower, never wrong.
        """
        store = self._staging_store
        if store is None:
            self._bump_mlp_quant(plan)
            return plan.predict(rows)
        key = getattr(self.model, "main_embedding_key", "emb")
        try:
            staged = store.stage(self.params[key], rows)
        except StagingOverflowError:
            self._mirror_store_stats()
            outs = []
            for chunk in store.split_for_staging(rows):
                staged = store.stage(self.params[key], chunk)
                self.params = {**self.params, key: staged}
                self._bump_mlp_quant(plan)
                outs.append(plan.predict(chunk))
            self._mirror_store_stats()
            return np.concatenate(outs)
        self.params = {**self.params, key: staged}
        self._mirror_store_stats()
        self._bump_mlp_quant(plan)
        return plan.predict(rows)

    def _bump_mlp_quant(self, plan: InferencePlan) -> None:
        """Count one execution of a quantized-compute plan: every int8
        matmul in its graph dispatches once per plan call."""
        n = getattr(plan.stats, "mlp_quant_matmuls", 0)
        if n:
            with self.stats.lock:
                self.stats.mlp_quant_matmuls += n

    def _hint_upcoming(self, limit: int = 4096) -> None:
        """Hand the still-queued requests' ids (batch t+1 while batch t is
        about to compute) to the store's async prefetch worker."""
        store = self._staging_store
        if store is None:
            return
        with self._cv:
            upcoming = [row for _, row, _ in
                        itertools.islice(self._queue, limit)]
        if upcoming:
            store.prefetch_hint(np.stack(upcoming))

    def refresh_cache(self) -> None:
        """Re-admit hot rows from observed traffic into the store's cache.

        Double-buffered refresh: the store builds the new cache tensors on
        the side (``store.refresh`` returns a fresh param subtree) while
        in-flight batches keep reading the old ones, then the engine
        publishes the new tree in one atomic reference swap. Every
        compiled plan takes the store tensors as runtime inputs
        (``InferencePlan.runtime_inputs``), so the **plan cache survives
        intact — a refresh never recompiles**. With a mesh, the fresh
        tensors are placed to the plans' runtime shardings
        (``EmbeddingStore.place`` — backing row-sharded, cache/index map
        replicated) *before* the swap, so the published tree never holds
        unplaced host arrays on a >1-device mesh. No-op for cacheless
        stores.
        """
        store = self.store
        if store is None or not store.refreshable:
            return
        # _drain_lock keeps the store's host-side admission state (counts,
        # index map, hit/miss stats) from being rebuilt mid-observe when a
        # refresh comes from outside the drain loop (ServingRuntime's
        # shared admission, a manual call); re-entrant for auto-refresh
        with self._drain_lock:
            key = getattr(self.model, "main_embedding_key", "emb")
            fresh = store.refresh(self.params[key])   # built on the side
            if self.mesh is not None:
                fresh = store.place(fresh, self.mesh)
            self.params = {**self.params, key: fresh}  # atomic publish
            with self.stats.lock:
                self.stats.emb_cache_refreshes = store.stats.refreshes
                self.stats.emb_cached_traffic_fraction = \
                    store.cached_traffic_fraction

    def _maybe_auto_refresh(self) -> None:
        if (self.refresh_every
                and self.stats.n_batches % self.refresh_every == 0):
            self.refresh_cache()

    # -- online deltas (live-trainer pushes) ----------------------------------
    def push_update(self, row_ids, new_rows) -> int:
        """Apply one batch of online ``(row_id, new_row)`` parameter
        deltas; returns how many (deduped) rows were applied.

        Rides the exact machinery a refresh uses: the store scatters the
        deltas into a *fresh* subtree on the side (``apply_deltas`` —
        backing + cache + staging tiers all updated, fp32 rows
        re-quantized for int8 stores), the engine places it to the plans'
        shardings when a mesh is set, and publishes it in one atomic
        reference swap **stamped with the next ``emb_version``** — bump
        and swap under one lock, so the version a compiled step observes
        is always monotonic (hard-asserted in ``_runtime_env``) and a
        plan binds either the entire pre-push set or the entire post-push
        set, never a mix. Zero recompiles: every updated tensor is a
        runtime plan input.

        Requires a refreshable store (``CachedStore``/``HostBackedStore``
        — raises ``ValueError`` otherwise: ``DenseStore`` tensors are
        baked constants of every compiled plan, unreachable by a swap).
        An engine sharing its store with another engine is unaffected by
        the *other* engine's pushes — its published subtree pins the
        pre-push version (the A/B / shadow-model scenario; see the
        ``HostBackedStore.apply_deltas`` caveat for the host tier).
        """
        store = self.store
        if store is None or not store.refreshable:
            raise ValueError(
                "push_update needs a refreshable embedding store "
                "(CachedStore / HostBackedStore); this engine serves "
                f"{store.describe() if store is not None else 'no store'}, "
                "whose tensors are compiled into plans as constants — "
                "rebuild params and re-compile to change them")
        with self._drain_lock:
            key = getattr(self.model, "main_embedding_key", "emb")
            fresh, n = store.apply_deltas(self.params[key], row_ids,
                                          new_rows)
            if n == 0:
                return 0
            if self.mesh is not None:
                fresh = store.place(fresh, self.mesh)
            with self.stats.lock:
                self.params = {**self.params, key: fresh}  # atomic publish
                self.stats.emb_version += 1
                self.stats.emb_delta_pushes += 1
                self.stats.emb_delta_rows += n
            return n

    def attach_delta_source(self, source) -> None:
        """Bind a :class:`~repro.serving.updates.DeltaSource` this engine
        pulls from (``pull_updates``, or the runtime's ``delta_every``
        cadence); its queue depth feeds the ``rows_behind`` /
        ``seconds_behind`` staleness gauges."""
        self._delta_source = source
        self.poll_staleness()

    def pull_updates(self, max_batches: int | None = None) -> int:
        """Drain the attached delta source (up to ``max_batches``)
        through :meth:`push_update`; returns total rows applied and
        refreshes the staleness gauges. 0 when no source is attached."""
        src = self._delta_source
        if src is None:
            return 0
        applied = 0
        pulled = 0
        while max_batches is None or pulled < max_batches:
            batch = src.next_batch()
            if batch is None:
                break
            pulled += 1
            applied += self.push_update(*batch)
        self.poll_staleness()
        return applied

    def poll_staleness(self) -> None:
        """Re-read the attached delta source's backlog into the
        ``rows_behind``/``seconds_behind`` gauges (no-op without a
        source). ``ServingRuntime.stats`` polls before every snapshot so
        the aggregate reflects the queue *now*, not as of the last
        pull."""
        src = self._delta_source
        rows = src.pending_rows() if src is not None else 0
        age = src.oldest_pending_s() if src is not None else 0.0
        with self.stats.lock:
            self.stats.rows_behind = int(rows)
            self.stats.seconds_behind = float(age)

    # -- plan cache ----------------------------------------------------------
    def _plan_key(self, bucket: int) -> PlanKey:
        return plan_key_for(self.model, self.level, bucket,
                            self.branch_order, sharded=self.mesh is not None,
                            compute_dtype=self.compute_dtype)

    def plan_for(self, bucket: int) -> InferencePlan:
        """Fetch (or compile-and-cache) the plan for one batch bucket."""
        key = self._plan_key(bucket)
        with self._compile_lock:
            plan = self._plans.get(key)
            if plan is not None:
                with self.stats.lock:
                    self.stats.cache_hits += 1
                return plan
            plan = compile_plan(self.model, self.params, self.level, bucket,
                                mesh=self.mesh, donate=self.donate,
                                branch_order=self.branch_order,
                                runtime_provider=self._runtime_env,
                                compute_dtype=self.compute_dtype)
            self._plans[key] = plan
            with self.stats.lock:
                self.stats.cache_misses += 1
                self.stats.compile_ms_per_bucket[int(bucket)] = \
                    plan.compile_ms
                for src, dst in _PLAN_MIRROR.items():
                    setattr(self.stats, dst,
                            getattr(self.stats, dst)
                            + getattr(plan.stats, src, 0))
        return plan

    @property
    def cached_plans(self) -> tuple[PlanKey, ...]:
        return tuple(self._plans)

    def warmup(self, buckets: Sequence[int] | None = None) -> None:
        """Compile every bucket the policy can emit (or an explicit list)."""
        for b in (buckets if buckets is not None else self.policy.buckets):
            self.plan_for(b)

    # -- request queue -------------------------------------------------------
    def submit(self, ids_row: np.ndarray) -> RequestFuture:
        """Queue one request (a per-field id vector of shape (k,));
        returns a future resolving to its score when its batch serves —
        or an already-failed future (:class:`QueueFullError`) when the
        queue is at ``max_queue_depth`` (backpressure)."""
        fut = RequestFuture()
        row = np.asarray(ids_row, dtype=np.int32)
        with self._cv:
            if (self.max_queue_depth is not None
                    and len(self._queue) >= self.max_queue_depth):
                with self.stats.lock:
                    self.stats.n_rejected += 1
                fut._fail(QueueFullError(
                    f"queue at max_queue_depth={self.max_queue_depth} "
                    f"({self.stats.n_rejected} rejected so far); the device "
                    "is not keeping up — shed load or raise the bound"))
                return fut
            self._queue.append((fut.t_submit, row, fut))
            with self.stats.lock:
                self.stats.queue_depth = len(self._queue)
            self._cv.notify()
        # outside _cv: the scheduler's pick loop holds its own lock while
        # polling next_ready (which takes _cv) — notifying it from inside
        # _cv would invert that order and deadlock
        sched = self._scheduler
        if sched is not None:
            sched.notify()
        return fut

    def submit_many(self, rows: Sequence[np.ndarray]) -> list[RequestFuture]:
        return [self.submit(r) for r in rows]

    def pending(self) -> int:
        with self._cv:
            return len(self._queue)

    # -- scheduler readiness view ---------------------------------------------
    def next_ready(self, now: float | None = None) -> ReadyBatch | None:
        """What this engine would dispatch next, and how urgent it is —
        the readiness view a :class:`~repro.serving.DeviceScheduler`
        polls instead of giving the engine its own worker thread.

        Nothing is dequeued. A full bucket is due immediately
        (``slack_ms == 0``); a partial batch carries the SLO slack left
        before its hold deadline — ``policy.partial_hold_ms``
        (``TimeoutBatch.max_wait_ms``) or, for policies without their own
        deadline (``FixedBatch``/``BucketedBatch``), the same few-tick
        grace the per-engine worker loop applies (``8·worker_tick_ms``).
        Returns None when the queue is empty or the policy would decline
        even a forced partial.
        """
        now = time.perf_counter() if now is None else now
        with self._cv:
            pending = len(self._queue)
            if not pending:
                return None
            oldest_wait_ms = (now - self._queue[0][0]) * 1e3
        d = self.policy.decide(pending, oldest_wait_ms, allow_partial=False)
        if d is not None:
            return ReadyBatch(d.take, d.bucket, 0.0, False)
        hold = self.policy.partial_hold_ms
        if hold is None:
            hold = 8 * self.worker_tick_ms
        # would the policy emit this partial if its deadline had passed?
        d = self.policy.decide(pending, math.inf, allow_partial=True)
        if d is None:
            return None
        return ReadyBatch(d.take, d.bucket, hold - oldest_wait_ms, True)

    def _note_worker_error(self, exc: BaseException) -> None:
        """Record a drain error swallowed off the caller's thread (the
        batch's futures already failed): counted in ``n_worker_errors``,
        last one kept for ``stop()`` to re-raise."""
        self.worker_error = exc
        with self.stats.lock:
            self.stats.n_worker_errors += 1

    # -- background worker ----------------------------------------------------
    def start(self) -> "InferenceEngine":
        """Spawn the background worker: drains the queue through the
        batching policy without caller polling, resolving futures as
        batches complete. Idempotent; returns self for chaining."""
        with self._cv:
            if self._worker is not None:
                return self
            self._running = True
            self._worker = threading.Thread(
                target=self._worker_loop, daemon=True,
                name=f"engine-worker-{getattr(self.model.spec, 'name', '?')}")
            self._worker.start()
        return self

    def stop(self, flush: bool = True) -> None:
        """Stop the worker (joins the thread). With ``flush`` (default),
        force-drain whatever is still queued so no future is left
        unresolved. Re-raises the last error a background drain swallowed
        (the failing batch's futures were already failed at the time;
        ``stats.n_worker_errors`` counts every one) — cleared on raise,
        so the call stays idempotent."""
        with self._cv:
            self._running = False
            self._cv.notify_all()
        worker, self._worker = self._worker, None
        if worker is not None:
            worker.join()
        if flush:
            self.flush()
        err, self.worker_error = self.worker_error, None
        if err is not None:
            raise err

    @property
    def running(self) -> bool:
        return self._worker is not None

    def _worker_loop(self) -> None:
        """Drain full buckets the moment they form; give partial batches a
        grace window of one ``worker_tick_ms`` for more arrivals before
        offering them to the policy as partials — so a trickle through
        ``FixedBatch``/``BucketedBatch`` still coalesces into real batches
        instead of serving every request the instant it lands, while
        ``TimeoutBatch`` keeps gating partials on its own explicit SLO
        (checked each tick until the oldest request ages past it). A
        steady trickle can keep the queue growing every tick, so an age
        backstop (8 ticks) guarantees partials are still offered to the
        policy — arrivals delay a partial batch, they cannot starve it."""
        tick = self.worker_tick_ms / 1e3
        while True:
            with self._cv:
                while self._running and not self._queue:
                    self._cv.wait()
                if not self._running:
                    return
            try:
                if self._serve(allow_partial=False, force=False).size:
                    continue                         # full buckets drained
                # nothing full: grace tick — drain partials once arrivals
                # pause (or the oldest request has waited long enough)
                with self._cv:
                    depth0 = len(self._queue)
                    if self._running and self._queue:
                        self._cv.wait(tick)
                    if not self._running:
                        return
                    grown = len(self._queue) > depth0
                    aged = bool(self._queue) and (
                        (time.perf_counter() - self._queue[0][0])
                        >= 8 * tick)
                if not grown or aged:
                    self._serve(allow_partial=True, force=False)
            except Exception as exc:                 # keep the loop alive;
                self._note_worker_error(exc)         # futures already failed

    # -- serving ---------------------------------------------------------------
    def serve_pending(self, allow_partial: bool = True) -> np.ndarray:
        """Drain the queue per the batching policy; scores in submit order.

        Requests the policy declines to batch (e.g. a partial batch with
        ``allow_partial=False``, or one still inside a timeout window) stay
        queued untouched. With the background worker running this is
        usually unnecessary (and may return empty — the worker got there
        first); the futures from ``submit`` are the async surface.
        """
        return self._serve(allow_partial=allow_partial, force=False)

    def flush(self) -> np.ndarray:
        """Drain everything now, overriding any timeout hold-back."""
        return self._serve(allow_partial=True, force=True)

    def _serve(self, *, allow_partial: bool, force: bool) -> np.ndarray:
        out: list[np.ndarray] = []
        with self._drain_lock:
            while True:
                scores = self._serve_step(allow_partial=allow_partial,
                                          force=force)
                if scores is None:
                    break
                out.append(scores)
        return np.concatenate(out) if out else np.empty((0,))

    def _serve_step(self, *, allow_partial: bool, force: bool
                    ) -> np.ndarray | None:
        """Serve at most *one* policy decision (one device batch); None
        when the policy declines. The unit a shared-pool scheduler
        dispatches — one batch per pick, so other engines' due batches
        interleave between ours — and the loop body of ``_serve``. The
        decision runs against the queue as it is *now*, so requests that
        arrived since a scheduler's readiness poll coalesce in."""
        with self._drain_lock:
            with self._cv:
                if not self._queue:
                    return None
                oldest_wait_ms = (
                    math.inf if force else
                    (time.perf_counter() - self._queue[0][0]) * 1e3)
                decision = self.policy.decide(
                    len(self._queue), oldest_wait_ms,
                    allow_partial=allow_partial)
                if decision is None:
                    return None
                items = [self._queue.popleft()
                         for _ in range(decision.take)]
                with self.stats.lock:
                    self.stats.queue_depth = len(self._queue)
            t_submit = [it[0] for it in items]
            try:
                # inside the try: a malformed row (ragged shape) must
                # fail its batch's futures, not strand them unresolved
                rows = np.stack([it[1] for it in items])
                self._observe_traffic(rows)
                plan = self.plan_for(decision.bucket)
                # batch t+1's ids go to the async prefetch worker now,
                # so its host-side miss gather overlaps batch t's
                # stage+compute below (no-op for non-staging stores)
                self._hint_upcoming()
                t0 = time.perf_counter()
                # plan.predict pads to the bucket shape and slices the
                # padding back off — one output transform shared with
                # the one-shot path; _predict_staged resolves staging
                # stores' misses first (pass-through otherwise)
                scores = self._predict_staged(plan, rows)
                t1 = time.perf_counter()
            except Exception as exc:
                for _, _, fut in items:
                    fut._fail(exc)
                raise
            lat = [(t1 - ts) * 1e3 for ts in t_submit]
            st = self.stats
            with st.lock:
                st.n_requests += decision.take
                st.n_batches += 1
                st.batches_per_bucket[decision.bucket] = (
                    st.batches_per_bucket.get(decision.bucket, 0) + 1)
                st.padded_rows_total += decision.bucket - decision.take
                st.compute_ms_total += (t1 - t0) * 1e3
                st.latency_ms.extend(lat)
            # futures resolve in submit order (items popped FIFO)
            for (_, _, fut), score, l in zip(items, scores, lat):
                fut._resolve(float(score), l)
            self._maybe_auto_refresh()
            return scores

    # -- one-shot --------------------------------------------------------------
    def predict(self, ids) -> np.ndarray:
        """One-shot scores for ``ids`` ((k,) or (b, k)), bypassing the
        queue. Reuses the plan cache: the smallest covering bucket, with
        batches beyond the largest bucket chunked through it — so the
        cache stays bounded by the policy's bucket set no matter what
        batch sizes callers throw at it."""
        ids = np.asarray(ids, dtype=np.int32)
        if ids.ndim == 1:
            ids = ids[None, :]
        b = ids.shape[0]
        largest = max(self.policy.buckets)
        if b > largest:
            return np.concatenate([self.predict(ids[i:i + largest])
                                   for i in range(0, b, largest)])
        bucket = min(bk for bk in self.policy.buckets if bk >= b)
        if self._staging_store is not None:
            # staging republishes self.params — hold the drain lock across
            # observe+stage+predict so a concurrent refresh can't interleave
            with self._drain_lock:
                self._observe_traffic(ids)
                return self._predict_staged(self.plan_for(bucket), ids)
        with self._drain_lock:    # observe never races a refresh/drain
            self._observe_traffic(ids)
        return self._predict_staged(self.plan_for(bucket), ids)
