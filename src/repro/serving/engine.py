"""InferenceEngine — the single serving surface over compiled plans.

The deployment story (paper Fig. 7) as three layers:

    plan  = compile_plan(model, params, "dual", 256)   # repro.core.plan
    eng   = InferenceEngine(model, params, policy=BucketedBatch())
    eng.submit(row); scores = eng.serve_pending()      # or eng.predict(ids)

The engine owns

* a **plan cache** keyed by ``(model, level, batch_bucket)`` — each batching
  bucket compiles once and is reused for every later batch of that shape
  (hit/miss counts are in ``stats``);
* a **batching policy** (``repro.serving.batching``) deciding how queued
  single-sample requests group into padded device batches;
* **latency accounting** separating queueing from compute (bounded rolling
  p50/p99 window — see ``EngineStats``), plus per-bucket compile counts and
  padding-waste fractions so benchmarks can quantify the bucketing win;
* an optional **embedding store** tier (``store=CachedStore(...)``): the
  engine feeds served id traffic to the store's admission counters,
  rebuilds the hot-row cache on ``refresh_cache()`` (or every
  ``refresh_every`` batches), and surfaces hit-rate / cached-traffic /
  refresh counters in ``stats`` — the HugeCTR inference-parameter-server
  loop over DPIFrame plans.

``CTRServingEngine`` (the old fixed-batch surface) remains as a deprecated
shim: ``InferenceEngine`` with ``FixedBatch(batch_size)``.
"""

from __future__ import annotations

import dataclasses
import math
import time
import warnings
from collections import deque
from typing import Sequence

import numpy as np
import jax

from repro.core.plan import InferencePlan, PlanKey, compile_plan, plan_key_for
from .batching import BatchPolicy, BucketedBatch, FixedBatch

__all__ = ["InferenceEngine", "EngineStats", "CTRServingEngine",
           "ServeStats"]


@dataclasses.dataclass
class EngineStats:
    """Serving counters: request/batch totals, latency split, plan-cache
    behaviour, padding waste per bucket, and embedding-store cache health.

    Latency accounting is a **bounded rolling window**: ``latency_ms``
    keeps only the most recent ``latency_window`` per-request samples
    (default 8192), so memory stays O(window) under sustained traffic.
    ``p50_ms``/``p99_ms`` are therefore *recent* percentiles — over the
    last ``latency_window`` served requests, not engine lifetime — which
    is what an SLO monitor wants anyway; lifetime totals remain exact in
    ``n_requests``/``compute_ms_total``.

    The ``emb_*`` counters mirror the engine's embedding store
    (``CachedStore``): row-lookup hits/misses against the current index
    map, cache rebuilds, and the fraction of observed traffic mass whose
    rows are currently cached (the fraction is a full-vocabulary scan, so
    it is refreshed at ``refresh_cache`` time, not per batch). All zero
    for the default ``DenseStore``.
    """
    n_requests: int = 0
    n_batches: int = 0
    compute_ms_total: float = 0.0
    latency_window: int = 8192
    latency_ms: deque = None
    cache_hits: int = 0
    cache_misses: int = 0
    compile_ms_per_bucket: dict = dataclasses.field(default_factory=dict)
    batches_per_bucket: dict = dataclasses.field(default_factory=dict)
    padded_rows_total: int = 0
    emb_cache_hits: int = 0
    emb_cache_misses: int = 0
    emb_cache_refreshes: int = 0
    emb_cached_traffic_fraction: float = 0.0

    def __post_init__(self):
        self.latency_ms = deque(self.latency_ms or (),
                                maxlen=self.latency_window)

    @property
    def p50_ms(self) -> float:
        return float(np.percentile(self.latency_ms, 50)) if self.latency_ms else 0.0

    @property
    def p99_ms(self) -> float:
        return float(np.percentile(self.latency_ms, 99)) if self.latency_ms else 0.0

    @property
    def padding_waste(self) -> float:
        """Fraction of served device rows that were padding."""
        rows = self.n_requests + self.padded_rows_total
        return self.padded_rows_total / rows if rows else 0.0

    @property
    def emb_cache_hit_rate(self) -> float:
        """Row-lookup hit rate of the embedding store's hot cache."""
        n = self.emb_cache_hits + self.emb_cache_misses
        return self.emb_cache_hits / n if n else 0.0


# deprecated alias — the old engine exported its stats under this name
ServeStats = EngineStats


class InferenceEngine:
    """Batched CTR inference over a cache of compiled ``InferencePlan``s.

    Args:
        model: any CTR model (``spec`` + ``build_graph``).
        params: parameter pytree.
        level: Fig.-8 executor level for every plan this engine compiles.
        policy: batching policy; default ``BucketedBatch()``.
        branch_order: breadth-first head-branch choice (§V-H).
        mesh: optional device mesh — plans shard the embedding tables
            row-wise over its model axis (placement delegated to the
            model/store ``partition_spec``).
        donate: donate input buffers to the compiled steps (level "dual"
            only; the eager levels ignore it).
        store: optional ``repro.embedding`` store (e.g. ``CachedStore``)
            to retrofit onto the model's main embedding table; ``params``
            are converted bit-exactly into the store's layout. The engine
            feeds every served id batch back to the store's admission
            counters and exposes hit-rate/refresh counters in ``stats``.
        refresh_every: rebuild the store's hot cache every N served
            batches (HugeCTR-style refresh interval). Each refresh
            invalidates this engine's compiled plans (they bake the old
            cache contents), so pick N large enough to amortize the
            recompiles. ``None`` = manual ``refresh_cache()`` only.
        latency_window: size of the rolling latency window behind
            ``stats.p50_ms``/``p99_ms`` (see ``EngineStats``).
    """

    def __init__(self, model, params, *, level: str = "dual",
                 policy: BatchPolicy | None = None,
                 branch_order: str = "longer_first",
                 mesh: jax.sharding.Mesh | None = None,
                 donate: bool = False,
                 store=None,
                 refresh_every: int | None = None,
                 latency_window: int = 8192):
        self.model = model
        if store is not None:
            params = model.use_store(store, params)
        self.params = params
        self.level = level
        self.policy = policy if policy is not None else BucketedBatch()
        self.branch_order = branch_order
        self.mesh = mesh
        self.donate = donate
        self.refresh_every = refresh_every
        self._plans: dict[PlanKey, InferencePlan] = {}
        self._queue: deque = deque()
        self.stats = EngineStats(latency_window=latency_window)

    # -- embedding store -----------------------------------------------------
    @property
    def store(self):
        """The model's main embedding store (DenseStore unless swapped)."""
        coll = getattr(self.model, "embedding", None)
        return getattr(coll, "store", None)

    def _observe_traffic(self, rows: np.ndarray) -> None:
        """Feed served ids to the store's admission counters and mirror
        the store's health into ``stats`` (host-side, outside jit). Only
        refreshable (cache-tiered) stores pay this — and the O(rows)
        cached-traffic scan is deferred to refresh time, not per batch."""
        coll = getattr(self.model, "embedding", None)
        if coll is None or not coll.store.refreshable:
            return
        coll.observe(rows)
        st, ss = self.stats, coll.store.stats
        st.emb_cache_hits = ss.hits
        st.emb_cache_misses = ss.misses
        st.emb_cache_refreshes = ss.refreshes

    def refresh_cache(self) -> None:
        """Re-admit hot rows from observed traffic into the store's cache
        and drop every compiled plan (their steps captured the old cache
        tensors). The next batch per bucket recompiles — the cost
        ``refresh_every`` amortizes. No-op for cacheless stores."""
        store = self.store
        if store is None or not store.refreshable:
            return
        key = getattr(self.model, "main_embedding_key", "emb")
        self.params = {**self.params,
                       key: store.refresh(self.params[key])}
        self._plans.clear()
        self.stats.emb_cache_refreshes = store.stats.refreshes
        self.stats.emb_cached_traffic_fraction = store.cached_traffic_fraction

    def _maybe_auto_refresh(self) -> None:
        if (self.refresh_every
                and self.stats.n_batches % self.refresh_every == 0):
            self.refresh_cache()

    # -- plan cache ----------------------------------------------------------
    def _plan_key(self, bucket: int) -> PlanKey:
        return plan_key_for(self.model, self.level, bucket,
                            self.branch_order, sharded=self.mesh is not None)

    def plan_for(self, bucket: int) -> InferencePlan:
        """Fetch (or compile-and-cache) the plan for one batch bucket."""
        key = self._plan_key(bucket)
        plan = self._plans.get(key)
        if plan is not None:
            self.stats.cache_hits += 1
            return plan
        self.stats.cache_misses += 1
        plan = compile_plan(self.model, self.params, self.level, bucket,
                            mesh=self.mesh, donate=self.donate,
                            branch_order=self.branch_order)
        self._plans[key] = plan
        self.stats.compile_ms_per_bucket[int(bucket)] = plan.compile_ms
        return plan

    @property
    def cached_plans(self) -> tuple[PlanKey, ...]:
        return tuple(self._plans)

    def warmup(self, buckets: Sequence[int] | None = None) -> None:
        """Compile every bucket the policy can emit (or an explicit list)."""
        for b in (buckets if buckets is not None else self.policy.buckets):
            self.plan_for(b)

    # -- request queue -------------------------------------------------------
    def submit(self, ids_row: np.ndarray) -> None:
        """Queue one request (a per-field id vector of shape (k,))."""
        self._queue.append((time.perf_counter(),
                            np.asarray(ids_row, dtype=np.int32)))

    def submit_many(self, rows: Sequence[np.ndarray]) -> None:
        for r in rows:
            self.submit(r)

    def pending(self) -> int:
        return len(self._queue)

    # -- serving ---------------------------------------------------------------
    def serve_pending(self, allow_partial: bool = True) -> np.ndarray:
        """Drain the queue per the batching policy; scores in submit order.

        Requests the policy declines to batch (e.g. a partial batch with
        ``allow_partial=False``, or one still inside a timeout window) stay
        queued untouched.
        """
        return self._serve(allow_partial=allow_partial, force=False)

    def flush(self) -> np.ndarray:
        """Drain everything now, overriding any timeout hold-back."""
        return self._serve(allow_partial=True, force=True)

    def _serve(self, *, allow_partial: bool, force: bool) -> np.ndarray:
        out: list[np.ndarray] = []
        while self._queue:
            oldest_wait_ms = (math.inf if force else
                              (time.perf_counter() - self._queue[0][0]) * 1e3)
            decision = self.policy.decide(len(self._queue), oldest_wait_ms,
                                          allow_partial=allow_partial)
            if decision is None:
                break
            items = [self._queue.popleft() for _ in range(decision.take)]
            t_submit = [it[0] for it in items]
            rows = np.stack([it[1] for it in items])
            self._observe_traffic(rows)
            plan = self.plan_for(decision.bucket)
            t0 = time.perf_counter()
            # plan.predict pads to the bucket shape and slices the padding
            # back off — one output transform shared with the one-shot path
            scores = plan.predict(rows)
            t1 = time.perf_counter()
            out.append(scores)
            st = self.stats
            st.n_requests += decision.take
            st.n_batches += 1
            st.batches_per_bucket[decision.bucket] = (
                st.batches_per_bucket.get(decision.bucket, 0) + 1)
            st.padded_rows_total += decision.bucket - decision.take
            st.compute_ms_total += (t1 - t0) * 1e3
            st.latency_ms.extend((t1 - ts) * 1e3 for ts in t_submit)
            self._maybe_auto_refresh()
        return np.concatenate(out) if out else np.empty((0,))

    # -- one-shot --------------------------------------------------------------
    def predict(self, ids) -> np.ndarray:
        """One-shot scores for ``ids`` ((k,) or (b, k)), bypassing the
        queue. Reuses the plan cache: the smallest covering bucket, with
        batches beyond the largest bucket chunked through it — so the
        cache stays bounded by the policy's bucket set no matter what
        batch sizes callers throw at it."""
        ids = np.asarray(ids, dtype=np.int32)
        if ids.ndim == 1:
            ids = ids[None, :]
        b = ids.shape[0]
        largest = max(self.policy.buckets)
        if b > largest:
            return np.concatenate([self.predict(ids[i:i + largest])
                                   for i in range(0, b, largest)])
        self._observe_traffic(ids)
        bucket = min(bk for bk in self.policy.buckets if bk >= b)
        return self.plan_for(bucket).predict(ids)


class CTRServingEngine(InferenceEngine):
    """Deprecated fixed-batch surface — use ``InferenceEngine`` with a
    batching policy from ``repro.serving.batching`` instead."""

    def __init__(self, model, params, *, batch_size: int = 256,
                 level: str = "dual", branch_order: str = "longer_first"):
        warnings.warn(
            "CTRServingEngine is deprecated; use InferenceEngine(model, "
            "params, policy=FixedBatch(batch_size)) — or BucketedBatch for "
            "lower padding waste.", DeprecationWarning, stacklevel=2)
        super().__init__(model, params, level=level,
                         branch_order=branch_order,
                         policy=FixedBatch(batch_size))
        self.batch_size = batch_size
