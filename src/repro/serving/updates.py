"""Delta sources — the trainer side of online model updates.

Real CTR serving consumes a continuous stream of parameter pushes from a
live trainer (HugeCTR's incremental-update pipeline): embedding rows keep
training while yesterday's snapshot serves, and the serving tier applies
``(row_id, new_row)`` deltas without ever dropping a request or a
compiled plan. This module is the intake side of that stream:

  ``DeltaSource``      the protocol an engine pulls from — batches of
                       deltas plus the staleness the engine reports
                       (``rows_behind`` / ``seconds_behind``).
  ``DeltaBuffer``      a thread-safe FIFO a trainer (or RPC handler)
                       ``feed``\\ s; tracks arrival times so staleness is
                       measured, not guessed.
  ``SyntheticTrainer`` a seeded, finite, deterministic delta stream over
                       the vocabulary — what ``launch/serve.py
                       --delta-every`` and the benchmarks drive.

The application side lives in ``InferenceEngine.push_update`` /
``pull_updates`` and ``ServingRuntime.push_update`` /
``attach_delta_stream``: every batch lands through the store's
``apply_deltas`` and the engine's double-buffered publish, stamping a new
monotonic ``emb_version`` — a compiled plan reads one published subtree
per step, so it sees the stream entirely-before or entirely-after each
push, never torn.
"""

from __future__ import annotations

import threading
import time
from collections import deque

import numpy as np

__all__ = ["DeltaSource", "DeltaBuffer", "SyntheticTrainer"]


class DeltaSource:
    """Protocol of a delta stream an engine can pull from.

    ``next_batch()`` returns the oldest unapplied ``(row_ids, new_rows)``
    pair — ids a 1-D integer array, rows the matching ``(n, d)``
    full-precision array — or ``None`` when the stream is (currently)
    drained. The two staleness accessors feed the engine's gauges:
    ``pending_rows()`` is how many delta rows are queued but unapplied
    (``rows_behind``), ``oldest_pending_s()`` how long the oldest of them
    has been waiting (``seconds_behind``; 0.0 when caught up).
    """

    def next_batch(self) -> tuple[np.ndarray, np.ndarray] | None:
        raise NotImplementedError

    def pending_rows(self) -> int:
        return 0

    def oldest_pending_s(self) -> float:
        return 0.0


class DeltaBuffer(DeltaSource):
    """Thread-safe FIFO between a trainer thread and the serving side.

    The producer calls :meth:`feed` with each push; the consumer (an
    engine's ``pull_updates``, or the runtime's ``delta_every`` cadence)
    drains it batch-by-batch via :meth:`next_batch`. Arrival timestamps
    ride along, so ``oldest_pending_s`` measures real queue age — the
    clock is injectable (``clock=``, default ``time.monotonic``) to keep
    staleness tests deterministic.
    """

    def __init__(self, clock=time.monotonic):
        self._q: deque[tuple[float, np.ndarray, np.ndarray]] = deque()
        self._lock = threading.Lock()
        self._pending = 0
        self._clock = clock

    def feed(self, row_ids, new_rows) -> int:
        """Queue one delta batch; returns rows now pending. Shapes are
        validated store-side at apply time (``validate_deltas``) — the
        buffer only requires ids and rows to agree on length."""
        row_ids = np.asarray(row_ids).reshape(-1)
        new_rows = np.asarray(new_rows)
        if new_rows.ndim == 1:
            new_rows = new_rows[None, :]
        if new_rows.shape[0] != row_ids.size:
            raise ValueError(f"{row_ids.size} row ids but "
                             f"{new_rows.shape[0]} rows")
        with self._lock:
            self._q.append((self._clock(), row_ids, new_rows))
            self._pending += int(row_ids.size)
            return self._pending

    def next_batch(self) -> tuple[np.ndarray, np.ndarray] | None:
        with self._lock:
            if not self._q:
                return None
            _, ids, rows = self._q.popleft()
            self._pending -= int(ids.size)
            return ids, rows

    def pending_rows(self) -> int:
        with self._lock:
            return self._pending

    def oldest_pending_s(self) -> float:
        with self._lock:
            if not self._q:
                return 0.0
            return max(0.0, self._clock() - self._q[0][0])


class SyntheticTrainer(DeltaSource):
    """A finite, seeded delta stream standing in for a live trainer.

    Emits ``n_batches`` batches of ``rows_per_batch`` deltas each, row
    ids drawn uniformly over ``[0, spec.zero_row)`` (the zero row and
    padding are never touched — stores reject them) and values from the
    same flat-scale normal family as ``init_dense_table``, so pushed rows
    are statistically indistinguishable from trained ones. Fully
    deterministic for a given ``seed``: the benchmark's structural
    counters and the A/B bit-exactness tests depend on replaying the
    identical stream.
    """

    def __init__(self, spec, rows_per_batch: int, n_batches: int,
                 seed: int = 0, clock=time.monotonic):
        if spec.zero_row < 1:
            raise ValueError("spec has no updatable rows")
        self.spec = spec
        self.rows_per_batch = int(rows_per_batch)
        self.n_batches = int(n_batches)
        self._emitted = 0
        self._lock = threading.Lock()
        self._seed = seed
        self._rng = np.random.default_rng(seed)
        self._clock = clock
        self._t_next = None   # arrival time of the current head batch

    def _make_batch(self) -> tuple[np.ndarray, np.ndarray]:
        ids = self._rng.integers(0, self.spec.zero_row,
                                 size=self.rows_per_batch)
        rows = (self._rng.standard_normal(
            (self.rows_per_batch, self.spec.dim)) * 0.05).astype(
                np.dtype(self.spec.dtype))
        return ids, rows

    def next_batch(self) -> tuple[np.ndarray, np.ndarray] | None:
        with self._lock:
            if self._emitted >= self.n_batches:
                return None
            self._emitted += 1
            self._t_next = None
            return self._make_batch()

    def pending_rows(self) -> int:
        with self._lock:
            return (self.n_batches - self._emitted) * self.rows_per_batch

    def oldest_pending_s(self) -> float:
        """Age since the head batch became available (tracked from the
        first staleness read after the previous pull — a stand-in for a
        real trainer's push timestamp)."""
        with self._lock:
            if self._emitted >= self.n_batches:
                return 0.0
            if self._t_next is None:
                self._t_next = self._clock()
            return max(0.0, self._clock() - self._t_next)

    def replay(self, seed: int | None = None) -> "SyntheticTrainer":
        """A fresh trainer emitting the identical stream (tests replay it
        against a second engine to check A/B divergence is exactly the
        un-pushed deltas)."""
        return SyntheticTrainer(self.spec, self.rows_per_batch,
                                self.n_batches,
                                seed=self._seed if seed is None else seed,
                                clock=self._clock)
