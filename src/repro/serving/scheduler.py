"""DeviceScheduler — cross-engine continuous batching on one device.

The per-engine worker model (one drain thread per hosted
``InferenceEngine``) is fine for a handful of models, but the PCDF
sponsored-search setting hosts *hundreds* of scenario/market variants
behind one router: N worker threads then contend blindly for one device
with no global view of whose latency SLO is about to blow. This module
replaces them with the continuous-batching shape HugeCTR-style inference
servers use:

* **one shared worker pool** (``pool_size`` threads, typically 2) owns
  the device for every attached engine — hosting N models costs
  ``pool_size`` threads, not N;
* each engine exposes a **readiness view** instead of draining itself:
  :meth:`InferenceEngine.next_ready` returns its candidate batch plus
  the SLO slack derived from its ``BatchPolicy`` (full buckets are due
  now; ``TimeoutBatch`` partials carry ``max_wait_ms − oldest_wait``;
  ``FixedBatch``/``BucketedBatch`` partials get the same few-tick grace
  the per-engine worker loop applied);
* the pool picks the due candidate with the **least slack** — the most
  overdue deadline serves first, so a starved low-traffic model's SLO
  beats a high-traffic model's endless full buckets the moment it comes
  due;
* dispatch **coalesces** same-model requests across intake streams: the
  engine re-decides against its *current* queue at dispatch time, so
  everything submitted between the readiness poll and the pick — from
  any number of submitter threads — rides the same device batch
  (possibly upgrading it to a larger bucket);
* per-model **device-time accounting**: every dispatch's wall time is
  charged to its engine, published as ``stats.device_time_share``
  (shares over one scheduler's engines sum to 1), alongside
  ``sched_dispatches`` and ``sched_preempted_slack_ms`` (milliseconds a
  due batch sat past its deadline while other models held the device).

Scores are **bit-exact with per-engine-worker mode**: each engine is
claimed by at most one pool thread at a time, so its queue still drains
FIFO through the same ``_serve_step`` path, and each request's score
depends only on its own row (padding rows are zeros), never on which
batch composition served it.

Standalone::

    sched = DeviceScheduler(pool_size=2)
    sched.attach("deepfm", eng_a)
    sched.attach("dcnv2", eng_b)
    sched.start()
    ... eng_a.submit(row).result() ...
    sched.stop()

or, the usual way, behind the router: ``ServingRuntime`` attaches every
hosted engine and starts the pool on ``rt.start()`` (its default
``scheduler="shared"`` mode; ``scheduler="per-engine"`` keeps the old
one-thread-per-engine behaviour).
"""

from __future__ import annotations

import threading
import time

from .engine import InferenceEngine, ReadyBatch

__all__ = ["DeviceScheduler"]

#: Cap on how long a pool thread sleeps waiting for a deadline: submits
#: and busy-releases notify the pool anyway, this just bounds the damage
#: if a notification is ever lost.
_MAX_WAIT_S = 0.25


class DeviceScheduler:
    """Shared worker pool + SLO-slack device-time scheduler.

    Args:
        pool_size: worker threads sharing the device across every
            attached engine. 2 is usually right on one device: one
            thread blocks in device compute while the other forms and
            stages the next batch. Thread count is ``pool_size``
            regardless of how many engines attach.

    Attributes:
        n_dispatches: total batches dispatched across all engines.
        device_ms: per-engine accumulated dispatch wall time (a copy).
        shares: per-engine fraction of total dispatched device time
            (sums to 1 once anything has dispatched).
    """

    def __init__(self, *, pool_size: int = 2):
        if pool_size < 1:
            raise ValueError(f"pool_size must be >= 1, got {pool_size}")
        self.pool_size = pool_size
        self._engines: dict[str, InferenceEngine] = {}
        # guards _engines/_busy/_device_ms/n_dispatches and is the pool's
        # wait target; never held across a dispatch (device compute)
        self._cv = threading.Condition(threading.Lock())
        self._busy: set[str] = set()
        self._device_ms: dict[str, float] = {}
        self._workers: list[threading.Thread] = []
        self._running = False
        self.n_dispatches = 0

    # -- registry -------------------------------------------------------------
    def attach(self, name: str, engine: InferenceEngine) -> InferenceEngine:
        """Host ``engine`` under ``name``. Idempotent for the same
        (name, engine) pair; an attached engine's ``submit`` wakes the
        pool instead of relying on a per-engine worker."""
        with self._cv:
            have = self._engines.get(name)
            if have is engine:
                return engine
            if have is not None:
                raise ValueError(f"name {name!r} already attached to a "
                                 "different engine")
            if engine._scheduler is not None and engine._scheduler is not self:
                raise ValueError(f"engine {name!r} already attached to "
                                 "another scheduler")
            self._engines[name] = engine
            self._device_ms.setdefault(name, 0.0)
            engine._scheduler = self
            self._cv.notify_all()
        return engine

    @property
    def engines(self) -> tuple[str, ...]:
        with self._cv:
            return tuple(self._engines)

    @property
    def device_ms(self) -> dict[str, float]:
        with self._cv:
            return dict(self._device_ms)

    @property
    def shares(self) -> dict[str, float]:
        with self._cv:
            total = sum(self._device_ms.values())
            return {n: (ms / total if total else 0.0)
                    for n, ms in self._device_ms.items()}

    # -- lifecycle ------------------------------------------------------------
    def start(self) -> "DeviceScheduler":
        """Spawn the pool (idempotent). ``pool_size`` threads total — the
        whole point: thread count no longer scales with model count."""
        with self._cv:
            if self._running:
                return self
            self._running = True
            self._workers = [
                threading.Thread(target=self._pool_loop, daemon=True,
                                 name=f"device-sched-{i}")
                for i in range(self.pool_size)]
        for t in self._workers:
            t.start()
        return self

    def stop(self) -> None:
        """Stop and join the pool. In-flight dispatches finish; queued
        requests stay queued (drain them via the engines' ``flush``/
        ``stop`` — ``ServingRuntime.stop`` does). Idempotent."""
        with self._cv:
            self._running = False
            self._cv.notify_all()
        workers, self._workers = self._workers, []
        for t in workers:
            t.join()

    @property
    def running(self) -> bool:
        return bool(self._workers)

    def notify(self) -> None:
        """Wake the pool (an attached engine got a submit)."""
        with self._cv:
            self._cv.notify_all()

    # -- the drain loop -------------------------------------------------------
    def _pick(self, now: float):
        """Least-slack-first over every idle engine's readiness view.

        Returns ``(name, candidate, wait_ms)``: the due candidate with
        the least slack (most overdue first — TimeoutBatch deadlines are
        global priorities), or ``name=None`` with ``wait_ms`` = time
        until the soonest pending deadline (None = nothing queued
        anywhere, sleep until notified). Caller holds ``_cv``.
        """
        best_name, best = None, None
        wait_ms = None
        for name, eng in self._engines.items():
            if name in self._busy:
                continue
            c = eng.next_ready(now)
            if c is None:
                continue
            if c.slack_ms <= 0.0:
                if best is None or c.slack_ms < best.slack_ms:
                    best_name, best = name, c
            else:
                wait_ms = (c.slack_ms if wait_ms is None
                           else min(wait_ms, c.slack_ms))
        return best_name, best, wait_ms

    def _pool_loop(self) -> None:
        while True:
            with self._cv:
                while True:
                    if not self._running:
                        return
                    name, cand, wait_ms = self._pick(time.perf_counter())
                    if name is not None:
                        # claim: one pool thread per engine at a time, so
                        # the queue drains FIFO exactly as a dedicated
                        # worker would (bit-exact scores, ordered futures)
                        self._busy.add(name)
                        break
                    timeout = (_MAX_WAIT_S if wait_ms is None
                               else min(max(wait_ms / 1e3, 1e-4),
                                        _MAX_WAIT_S))
                    self._cv.wait(timeout)
            eng = self._engines[name]
            served = False
            t0 = time.perf_counter()
            try:
                scores = eng._serve_step(allow_partial=cand.partial,
                                         force=False)
                served = scores is not None
            except Exception as exc:
                # same contract as the per-engine worker loop: the batch's
                # futures already failed; count it, keep the pool alive
                eng._note_worker_error(exc)
            dt_ms = (time.perf_counter() - t0) * 1e3
            with self._cv:
                self._busy.discard(name)
                if served:
                    self.n_dispatches += 1
                    self._device_ms[name] += dt_ms
                    self._publish_shares(name, cand)
                # a freed engine may already have the next due batch —
                # and other threads may be sleeping on a stale deadline
                self._cv.notify_all()

    def _publish_shares(self, served_name: str, cand: ReadyBatch) -> None:
        """Mirror device-time accounting into engine stats (holds _cv;
        engine stats locks nest strictly inside it)."""
        total = sum(self._device_ms.values())
        for name, eng in self._engines.items():
            with eng.stats.lock:
                eng.stats.device_time_share = (
                    self._device_ms[name] / total if total else 0.0)
        eng = self._engines[served_name]
        overdue = max(0.0, -cand.slack_ms) if cand.partial else 0.0
        with eng.stats.lock:
            eng.stats.sched_dispatches += 1
            eng.stats.sched_preempted_slack_ms += overdue
