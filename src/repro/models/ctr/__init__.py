"""CTR model zoo — the paper's four evaluation models."""

from .common import CTRModel, CTRModelSpec, bce_loss
from .dcn import DCN
from .dcnv2 import DCNv2
from .deepfm import DeepFM
from .widedeep import WideDeep

CTR_MODELS = {
    "dcn": DCN,
    "dcnv2": DCNv2,
    "widedeep": WideDeep,
    "deepfm": DeepFM,
}


def make_ctr_model(name: str, spec: CTRModelSpec) -> CTRModel:
    return CTR_MODELS[name](spec)


__all__ = ["CTRModel", "CTRModelSpec", "CTR_MODELS", "make_ctr_model",
           "DCN", "DCNv2", "WideDeep", "DeepFM", "bce_loss"]
