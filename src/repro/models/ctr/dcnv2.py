"""DCNv2 (Wang et al. 2021): cross network with full-matrix projection.

Explicit branch: x_{l+1} = x0 ⊙ (W_l x_l + b_l) + x_l — the W_l GEMM feeds
the elementwise tail fused by C5 into the ``cross_v2_tail`` Pallas kernel
(bias lives inside the GEMM op, so one global hint serves every layer).
Implicit branch: deep MLP. Head: concat → linear → logit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import Op, OpGraph

from .common import (CTRModel, emit_embedding_ops, emit_mlp_ops, init_dense,
                     mlp_init)


class DCNv2(CTRModel):
    def init(self, key: jax.Array) -> dict:
        spec = self.spec
        dtype = jnp.dtype(spec.dtype)
        keys = jax.random.split(key, 3 + spec.cross_layers)
        d_in = spec.input_dim
        params: dict = {
            "emb": self.embedding.init(keys[0]),
            "mlp": mlp_init(keys[1], (d_in, *spec.hidden), dtype),
            "head": init_dense(keys[2], d_in + spec.hidden[-1], 1, dtype),
            "cross": [init_dense(keys[3 + li], d_in, d_in, dtype)
                      for li in range(spec.cross_layers)],
        }
        return params

    def build_graph(self, params: dict, level: str,
                    compute_dtype: str = "fp32") -> OpGraph:
        g = OpGraph(["ids"])
        emit_embedding_ops(g, self.embedding, params, level)

        # explicit: cross network v2
        cur = "x_embed"
        n_layers = len(params["cross"])
        for li, layer in enumerate(params["cross"]):
            w, b = layer["w"], layer["b"]
            g.add(Op(f"cross_gemm{li}",
                     lambda x, _w=w, _b=b: x @ _w + _b,
                     (cur,), f"xw{li}", is_gemm=True, module="explicit"))
            out_edge = ("explicit_out" if li == n_layers - 1
                        else f"x_cross{li}")
            g.add(Op(f"cross_mul{li}",
                     lambda x0, xw: x0 * xw,
                     ("x_embed", f"xw{li}"), f"cm{li}",
                     module="explicit", fused_hint="cross_v2_tail"))
            g.add(Op(f"cross_res{li}",
                     lambda m, x: m + x,
                     (f"cm{li}", cur), out_edge,
                     module="explicit", fused_hint="cross_v2_tail"))
            cur = out_edge

        # implicit: deep MLP
        deep_out = emit_mlp_ops(g, params["mlp"], "x_embed", "implicit",
                                prefix="deep", final_act=True,
                                compute_dtype=compute_dtype)

        # head
        hw, hb = params["head"]["w"], params["head"]["b"]
        g.add(Op("head_concat",
                 lambda a, b_: jnp.concatenate([a, b_], axis=1),
                 ("explicit_out", deep_out), "stacked", module="head"))
        g.add(Op("head_gemm", lambda h: h @ hw + hb, ("stacked",),
                 "logit", is_gemm=True, module="head"))
        return g
