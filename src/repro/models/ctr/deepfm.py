"""DeepFM (Guo et al. 2017).

Explicit branch: factorization machine — first-order d=1 lookup-sum plus the
second-order term 0.5·Σ_d[(Σ_k v)²−Σ_k v²] emitted as a fine-grained
non-GEMM chain (square/sum/sub/scale) that C5 fuses into the fused_fm
Pallas kernel. Implicit branch: deep MLP sharing the same embeddings.
Head: fm_linear + fm_second + deep_logit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import FusedEmbeddingCollection, Op, OpGraph

from .common import (CTRModel, emit_embedding_ops, emit_mlp_ops, init_dense,
                     mlp_init)


class DeepFM(CTRModel):
    def __init__(self, spec, store=None):
        super().__init__(spec, store=store)
        # FM first-order d=1 tables are tiny — always dense
        self.wide_embedding = FusedEmbeddingCollection(spec.wide_spec())

    def init(self, key: jax.Array) -> dict:
        spec = self.spec
        dtype = jnp.dtype(spec.dtype)
        keys = jax.random.split(key, 4)
        return {
            "emb": self.embedding.init(keys[0]),
            "fm_w": self.wide_embedding.init(keys[1]),
            "fm_bias": jnp.zeros((1,), dtype=dtype),
            "mlp": mlp_init(keys[2], (spec.input_dim, *spec.hidden), dtype),
            "deep_head": init_dense(keys[3], spec.hidden[-1], 1, dtype),
        }

    def embedding_collections(self) -> dict:
        return {self.main_embedding_key: self.embedding,
                "fm_w": self.wide_embedding}

    def build_graph(self, params: dict, level: str,
                    compute_dtype: str = "fp32") -> OpGraph:
        spec = self.spec
        g = OpGraph(["ids"])
        emit_embedding_ops(g, self.embedding, params, level)

        # explicit (FM): first-order linear term
        fb = params["fm_bias"]
        g.add(Op("fm_lin_lookup",
                 lambda ids: self.wide_embedding.apply(params["fm_w"], ids),
                 ("ids",), "fm_lin_terms", module="explicit"))
        g.add(Op("fm_lin_sum",
                 lambda t, _b=fb: jnp.sum(t, axis=1, keepdims=True) + _b,
                 ("fm_lin_terms",), "fm_linear", module="explicit"))

        # second-order term as a fine-grained non-GEMM chain (fused by C5
        # into the fused_fm Pallas kernel — all ops share one hint)
        k, d = spec.k, spec.embed_dim
        # (reshape is deliberately *not* hinted: the fused_fm kernel's
        # signature is (b, k, d), so the hinted group starts at fm_sum_k)
        g.add(Op("fm_reshape",
                 lambda x: x.reshape(x.shape[0], k, d),
                 ("x_embed",), "v", module="explicit"))
        g.add(Op("fm_sum_k", lambda v: jnp.sum(v, axis=1),
                 ("v",), "s", module="explicit",
                 fused_hint="fm_second_order"))
        g.add(Op("fm_sq_s", lambda s: s * s, ("s",), "ss",
                 module="explicit", fused_hint="fm_second_order"))
        g.add(Op("fm_sq_v", lambda v: v * v, ("v",), "v2",
                 module="explicit", fused_hint="fm_second_order"))
        g.add(Op("fm_sum_v2", lambda v2: jnp.sum(v2, axis=1),
                 ("v2",), "sv2", module="explicit",
                 fused_hint="fm_second_order"))
        g.add(Op("fm_final",
                 lambda ss, sv2: 0.5 * jnp.sum(ss - sv2, axis=-1,
                                               keepdims=True),
                 ("ss", "sv2"), "fm_second", module="explicit",
                 fused_hint="fm_second_order"))
        g.add(Op("fm_add", lambda a, b: a + b, ("fm_linear", "fm_second"),
                 "explicit_out", module="explicit"))

        # implicit: deep MLP
        deep_out = emit_mlp_ops(g, params["mlp"], "x_embed", "implicit",
                                prefix="deep", final_act=True,
                                compute_dtype=compute_dtype)
        hw, hb = params["deep_head"]["w"], params["deep_head"]["b"]
        g.add(Op("deep_head", lambda h: h @ hw + hb, (deep_out,),
                 "implicit_out", is_gemm=True, module="implicit"))

        # head
        g.add(Op("head_add", lambda a, b: a + b,
                 ("explicit_out", "implicit_out"), "logit", module="head"))
        return g
