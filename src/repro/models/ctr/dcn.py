"""DCN (Deep & Cross Network, Wang et al. 2017) — paper's Figure-1 example.

Explicit branch: cross network v1,  x_{l+1} = x0 · (x_l ⊤ w_l) + b_l + x_l
(the (x_l·w_l) contraction is the GEMM; the remaining elementwise chain is
the non-GEMM tail that C5 fuses — per-layer Pallas kernel fused_cross_v1).
Implicit branch: deep MLP. Head: concat → linear → logit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import Op, OpGraph
from repro.core.opgraph import register_fused_kernel
from repro.kernels import ops as kops
from repro.kernels import ref as kref

from .common import (CTRModel, CTRModelSpec, emit_embedding_ops, emit_mlp_ops,
                     init_dense, mlp_init)


class DCN(CTRModel):
    def init(self, key: jax.Array) -> dict:
        spec = self.spec
        dtype = jnp.dtype(spec.dtype)
        keys = jax.random.split(key, 4 + spec.cross_layers)
        d_in = spec.input_dim
        params: dict = {
            "emb": self.embedding.init(keys[0]),
            "mlp": mlp_init(keys[1], (d_in, *spec.hidden), dtype),
            "head": init_dense(keys[2], d_in + spec.hidden[-1], 1, dtype),
        }
        cross = []
        for li in range(spec.cross_layers):
            kw = keys[3 + li]
            cross.append({
                "w": jax.random.normal(kw, (d_in, 1), dtype=dtype)
                     * (1.0 / jnp.sqrt(d_in)),
                "b": jnp.zeros((d_in,), dtype=dtype),
            })
        params["cross"] = cross
        return params

    def build_graph(self, params: dict, level: str,
                    compute_dtype: str = "fp32") -> OpGraph:
        g = OpGraph(["ids"])
        emit_embedding_ops(g, self.embedding, params, level)

        # explicit: cross network v1
        cur = "x_embed"
        n_layers = len(params["cross"])
        for li, layer in enumerate(params["cross"]):
            w, b = layer["w"], layer["b"]
            g.add(Op(f"cross_gemm{li}", lambda x, _w=w: x @ _w,
                     (cur,), f"xlw{li}", is_gemm=True, module="explicit"))
            hint = f"dcn_v1_tail_{id(self)}_{li}"
            register_fused_kernel(hint, _make_v1_kernel(b, first=(li == 0)))
            out_edge = ("explicit_out" if li == n_layers - 1
                        else f"x_cross{li}")
            g.add(Op(f"cross_mul{li}",
                     lambda x0, xlw: x0 * xlw,
                     ("x_embed", f"xlw{li}"), f"cm{li}",
                     module="explicit", fused_hint=hint))
            g.add(Op(f"cross_addres{li}",
                     lambda m, x, _b=b: m + _b[None, :] + x,
                     (f"cm{li}", cur), out_edge,
                     module="explicit", fused_hint=hint))
            cur = out_edge

        # implicit: deep MLP
        deep_out = emit_mlp_ops(g, params["mlp"], "x_embed", "implicit",
                                prefix="deep", final_act=True,
                                compute_dtype=compute_dtype)

        # head
        hw, hb = params["head"]["w"], params["head"]["b"]
        g.add(Op("head_concat",
                 lambda a, b_: jnp.concatenate([a, b_], axis=1),
                 ("explicit_out", deep_out), "stacked", module="head"))
        g.add(Op("head_gemm", lambda h: h @ hw + hb, ("stacked",),
                 "logit", is_gemm=True, module="head"))
        return g


def _make_v1_kernel(bias, first: bool):
    """Per-layer closure (bias is a parameter, not a graph edge).

    Composed-subgraph signature after fusion: layer 0 receives (x0, xlw)
    because x_l == x0 is deduplicated; later layers receive (x0, xlw, x_l).
    """
    def f(x0, xlw, x=None):
        if x is None:
            x = x0
        if kops.on_tpu():
            return kops.fused_cross_v1(x0, xlw, bias, x)
        return kref.ref_cross_v1_elementwise(x0, xlw, bias, x)
    return f
