"""Wide&Deep (Cheng et al. 2016).

Explicit (wide) branch: per-field linear weights — a d=1 fused lookup plus a
reduce-sum (pure embedding work, which is why the paper sees its largest
speedups here). Implicit branch: deep MLP. Head: wide_logit + deep_logit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import FusedEmbeddingCollection, Op, OpGraph

from .common import (CTRModel, emit_embedding_ops, emit_mlp_ops, init_dense,
                     mlp_init)


class WideDeep(CTRModel):
    def __init__(self, spec, store=None):
        super().__init__(spec, store=store)
        # wide d=1 tables are tiny — always dense, never worth tiering
        self.wide_embedding = FusedEmbeddingCollection(spec.wide_spec())

    def init(self, key: jax.Array) -> dict:
        spec = self.spec
        dtype = jnp.dtype(spec.dtype)
        keys = jax.random.split(key, 4)
        return {
            "emb": self.embedding.init(keys[0]),
            "wide": self.wide_embedding.init(keys[1]),
            "wide_bias": jnp.zeros((1,), dtype=dtype),
            "mlp": mlp_init(keys[2], (spec.input_dim, *spec.hidden), dtype),
            "deep_head": init_dense(keys[3], spec.hidden[-1], 1, dtype),
        }

    def embedding_collections(self) -> dict:
        return {self.main_embedding_key: self.embedding,
                "wide": self.wide_embedding}

    def build_graph(self, params: dict, level: str,
                    compute_dtype: str = "fp32") -> OpGraph:
        g = OpGraph(["ids"])
        emit_embedding_ops(g, self.embedding, params, level)

        # explicit (wide): d=1 lookup + sum — entirely embedding-style work.
        # naive level keeps it per-field; fused levels use the mega-table.
        wb = params["wide_bias"]
        if level == "naive":
            offs = self.wide_embedding.spec.offsets
            k = self.spec.k
            wide_table = self.wide_embedding.dense_view(params["wide"])
            for i in range(k):
                g.add(Op(f"wide_lookup_{i}",
                         lambda ids, _i=i, _o=int(offs[i]):
                             jnp.take(wide_table, ids[:, _i] + _o, axis=0),
                         ("ids",), f"wide_f{i}", module="explicit"))
            g.add(Op("wide_concat",
                     lambda *cols: jnp.concatenate(cols, axis=1),
                     tuple(f"wide_f{i}" for i in range(k)),
                     "wide_terms", module="explicit"))
        else:
            g.add(Op("wide_fused",
                     lambda ids: self.wide_embedding.apply(params["wide"],
                                                           ids),
                     ("ids",), "wide_terms", module="explicit"))
        g.add(Op("wide_sum",
                 lambda t, _b=wb: jnp.sum(t, axis=1, keepdims=True) + _b,
                 ("wide_terms",), "explicit_out", module="explicit"))

        # implicit: deep MLP + its own head GEMM to a logit
        deep_out = emit_mlp_ops(g, params["mlp"], "x_embed", "implicit",
                                prefix="deep", final_act=True,
                                compute_dtype=compute_dtype)
        hw, hb = params["deep_head"]["w"], params["deep_head"]["b"]
        g.add(Op("deep_head", lambda h: h @ hw + hb, (deep_out,),
                 "implicit_out", is_gemm=True, module="implicit"))

        # head: sum of branch logits
        g.add(Op("head_add", lambda a, b: a + b,
                 ("explicit_out", "implicit_out"), "logit", module="head"))
        return g
