"""Shared pieces of the CTR model zoo: inits, MLP op emission, kernel hooks.

Every model exposes the same interface (``CTRModel``):

  spec             CTRModelSpec (embedding schema + net sizes)
  init(key)        -> params pytree
  build_graph(params, level) -> OpGraph   (consumed by DualParallelExecutor)
  apply(params, ids) -> logits (b, 1)     (differentiable forward = the
                                           graph at "dual" semantics; used
                                           by the trainer)
  loss(params, batch) -> scalar BCE

Graph modules: "embedding" -> ("explicit" ∥ "implicit") -> "head", matching
the paper's decomposition, with GEMMs flagged and non-GEMM tails carrying
``fused_hint`` so the C5 pass can swap in the Pallas kernels.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import FusedEmbeddingCollection, FusedEmbeddingSpec, Op, OpGraph
from repro.core.opgraph import register_fused_kernel
from repro.embedding import runtime_edge
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.quant import quantize_channels

__all__ = ["CTRModelSpec", "CTRModel", "init_dense", "mlp_init",
           "emit_embedding_ops", "emit_mlp_ops", "bce_loss"]


@dataclasses.dataclass(frozen=True)
class CTRModelSpec:
    """Static CTR model description (paper §V-A configuration space)."""
    name: str
    field_sizes: tuple[int, ...]
    embed_dim: int = 16                      # paper: 16 / 32
    hidden: tuple[int, ...] = (256, 256, 256)  # paper: 256/512/1024 ×3
    cross_layers: int = 3                    # paper: 3 (DCN/DCNv2)
    dtype: str = "float32"

    @property
    def k(self) -> int:
        return len(self.field_sizes)

    @property
    def input_dim(self) -> int:
        return self.k * self.embed_dim

    def embedding_spec(self) -> FusedEmbeddingSpec:
        return FusedEmbeddingSpec(field_sizes=self.field_sizes,
                                  dim=self.embed_dim, dtype=self.dtype)

    def wide_spec(self) -> FusedEmbeddingSpec:
        """d=1 tables for linear terms (Wide&Deep / FM first order)."""
        return FusedEmbeddingSpec(field_sizes=self.field_sizes, dim=1,
                                  dtype=self.dtype)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def init_dense(key, fan_in: int, fan_out: int, dtype) -> dict:
    scale = np.sqrt(2.0 / (fan_in + fan_out))
    w = jax.random.normal(key, (fan_in, fan_out), dtype=dtype) * scale
    return {"w": w, "b": jnp.zeros((fan_out,), dtype=dtype)}


def mlp_init(key, dims: tuple[int, ...], dtype) -> list[dict]:
    keys = jax.random.split(key, len(dims) - 1)
    return [init_dense(k, dims[i], dims[i + 1], dtype)
            for i, k in enumerate(keys)]


# ---------------------------------------------------------------------------
# graph emission helpers
# ---------------------------------------------------------------------------

def emit_embedding_ops(g: OpGraph, emb: FusedEmbeddingCollection,
                       params: dict, level: str, *, out: str = "x_embed",
                       prefix: str = "emb") -> None:
    """Embedding module ops over the store subtree at ``params[prefix]``.

    ``naive`` = k serial gathers + concat off the store's dense view (the
    baseline the paper measures against); otherwise ONE fused lookup
    through whatever tiers the store keeps (mega-table or cache+backing).

    Refreshable stores declare ``runtime_keys``: those leaves become extra
    *graph inputs* (edge names from :func:`repro.embedding.runtime_edge`)
    instead of closed-over constants, so a compiled plan keeps working
    across cache refreshes — the caller feeds the current tensors per
    step (``compile_plan`` wires this; ``CTRModel.graph_env`` builds the
    matching env for the eager/training path).
    """
    store_params = params[prefix]
    if level == "naive":
        k = emb.spec.k
        offs = emb.spec.offsets
        table = emb.dense_view(store_params)
        for i in range(k):
            def one_field(ids, _i=i, _o=int(offs[i])):
                return jnp.take(table, ids[:, _i] + _o, axis=0)
            g.add(Op(f"{prefix}_lookup_{i}", one_field, ("ids",),
                     f"{prefix}_f{i}", module="embedding"))
        g.add(Op(f"{prefix}_concat",
                 lambda *cols: jnp.concatenate(cols, axis=1),
                 tuple(f"{prefix}_f{i}" for i in range(k)),
                 out, module="embedding"))
        return
    rt = tuple(emb.store.runtime_keys)
    if rt:
        static = {k_: v for k_, v in store_params.items() if k_ not in rt}
        edges = tuple(runtime_edge(prefix, leaf) for leaf in rt)
        for e in edges:
            g.add_input(e)

        def fused_runtime(ids, *leaves):
            return emb.apply({**static, **dict(zip(rt, leaves))}, ids)

        g.add(Op(f"{prefix}_fused", fused_runtime, ("ids",) + edges,
                 out, module="embedding"))
    else:
        g.add(Op(f"{prefix}_fused",
                 lambda ids: emb.apply(store_params, ids),
                 ("ids",), out, module="embedding"))


def emit_mlp_ops(g: OpGraph, layers: list[dict], src: str, module: str,
                 prefix: str = "mlp", final_act: bool = False,
                 compute_dtype: str = "fp32") -> str:
    """Per-layer GEMM (flagged) + ReLU (non-GEMM, fusable).

    ``compute_dtype="int8"`` swaps each fp32 GEMM + ReLU pair for ONE
    fused quantized op (``kops.dense_matmul_q8``): the weight matrix is
    quantized per output channel HERE, once at graph-build time — MLP
    weights are never runtime inputs, so the baked int8 constants keep
    refresh recompile-free by construction — while activations quantize
    per row dynamically inside the op, and dequant + bias + ReLU run in
    the kernel epilogue. Structural counters land in ``g.meta`` and
    surface as the ``mlp_quant_*`` fields of ``ExecutorStats``.
    """
    if compute_dtype not in ("fp32", "int8"):
        raise ValueError(f"unknown compute_dtype {compute_dtype!r}")
    cur = src
    n = len(layers)
    for li, layer in enumerate(layers):
        w, b = layer["w"], layer["b"]
        act = li < n - 1 or final_act
        if compute_dtype == "int8":
            qw, wscale = quantize_channels(w)
            out_edge = f"{prefix}_a{li}" if act else f"{prefix}_h{li}"
            g.add(Op(f"{prefix}_q8gemm{li}",
                     lambda h, _qw=qw, _ws=wscale, _b=b, _act=act:
                         kops.dense_matmul_q8(h, _qw, _ws, _b, relu=_act),
                     (cur,), out_edge, is_gemm=True, module=module))
            cur = out_edge
            fan_in, fan_out = int(w.shape[0]), int(w.shape[1])
            # int8 payload + one fp32 scale per output channel, vs 4 B/elt
            q8_bytes = fan_in * fan_out + 4 * fan_out
            g.meta["compute_dtype"] = "int8"
            g.meta["mlp_quant_matmuls"] = \
                g.meta.get("mlp_quant_matmuls", 0) + 1
            g.meta["mlp_quant_weight_bytes"] = \
                g.meta.get("mlp_quant_weight_bytes", 0) + q8_bytes
            g.meta["mlp_quant_weight_bytes_saved"] = \
                g.meta.get("mlp_quant_weight_bytes_saved", 0) \
                + 4 * fan_in * fan_out - q8_bytes
            continue
        g.add(Op(f"{prefix}_gemm{li}",
                 lambda h, _w=w, _b=b: h @ _w + _b,
                 (cur,), f"{prefix}_h{li}", is_gemm=True, module=module))
        cur = f"{prefix}_h{li}"
        if act:
            g.add(Op(f"{prefix}_relu{li}",
                     lambda h: jnp.maximum(h, 0),
                     (cur,), f"{prefix}_a{li}", module=module,
                     fused_hint="relu"))
            cur = f"{prefix}_a{li}"
    return cur


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def bce_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Numerically-stable binary cross entropy from logits."""
    logits = logits.reshape(-1).astype(jnp.float32)
    labels = labels.reshape(-1).astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * labels +
                    jnp.log1p(jnp.exp(-jnp.abs(logits))))


# ---------------------------------------------------------------------------
# Pallas kernel registration for the C5 pattern registry
# ---------------------------------------------------------------------------

def _dispatch(pallas_fn: Callable, jnp_fn: Callable) -> Callable:
    """Use the Pallas kernel on TPU, identical jnp math elsewhere (the CPU
    benchmarks must not time interpret mode)."""
    def f(*args):
        if kops.on_tpu():
            return pallas_fn(*args)
        return jnp_fn(*args)
    return f


def _cross_v2_tail(x0, xw, x=None):
    # layer 0 dedups x_l == x0 into a 2-arg call
    if x is None:
        x = x0
    if kops.on_tpu():
        return kops.fused_cross_v2(x0, xw, x)
    return kref.ref_cross_v2_elementwise(x0, xw, x)


register_fused_kernel("cross_v2_tail", _cross_v2_tail)

register_fused_kernel(
    "fm_second_order",
    _dispatch(lambda v: kops.fused_fm_second_order(v),
              lambda v: kref.ref_fm_second_order(v)[:, None]))


# ---------------------------------------------------------------------------
# model base
# ---------------------------------------------------------------------------

class CTRModel:
    """Base: shares embedding init/placement + trainer-facing apply/loss.

    The embedding path runs through ``repro.embedding``: every model keys
    its param tree with one subtree per :class:`FusedEmbeddingCollection`
    (``params["emb"]`` for the main table; wide/FM variants add their own),
    whose internal layout belongs to the collection's store. Pass
    ``store=`` (e.g. ``repro.embedding.CachedStore``) to tier the main
    table; default is the monolithic ``DenseStore``.
    """

    #: param-tree key of the main (tierable) embedding subtree — the one
    #: ``store=``/``use_store``/``refresh_cache`` operate on
    main_embedding_key = "emb"

    def __init__(self, spec: CTRModelSpec, store=None):
        self.spec = spec
        self.embedding = FusedEmbeddingCollection(spec.embedding_spec(),
                                                  store=store)

    # subclasses fill these in -------------------------------------------------
    def init(self, key: jax.Array) -> dict:
        raise NotImplementedError

    def build_graph(self, params: dict, level: str,
                    compute_dtype: str = "fp32") -> OpGraph:
        raise NotImplementedError

    # embedding-store surface --------------------------------------------------
    def embedding_collections(self) -> dict:
        """Param-tree key -> collection, for every embedding subtree this
        model owns. Placement and store plumbing walk this — never param
        *names* (the old ``"mega" in names`` heuristic broke on renames)."""
        return {self.main_embedding_key: self.embedding}

    def partition_spec(self, params: dict, model_axis: str = "model"):
        """Mesh placement for ``params``: embedding subtrees per their
        store's ``partition_spec`` (vocab-parallel tables, replicated cache
        tiers), everything else replicated (CTR dense nets are
        latency-bound)."""
        from jax.sharding import PartitionSpec as P
        specs = jax.tree.map(lambda _: P(), params)
        for key, coll in self.embedding_collections().items():
            if key in params:
                specs[key] = coll.partition_spec(model_axis)
        return specs

    def store_runtime_env(self, params: dict) -> dict:
        """Edge name -> tensor for every runtime store input this model's
        graphs declare (see ``emit_embedding_ops``): the leaves refreshable
        stores swap at refresh time. Empty for all-dense models."""
        env = {}
        for key, coll in self.embedding_collections().items():
            sub = params.get(key)
            if sub is None:
                continue
            for leaf in coll.store.runtime_keys:
                env[runtime_edge(key, leaf)] = sub[leaf]
        return env

    def graph_env(self, params: dict, ids: jax.Array) -> dict:
        """The full input env for executing a graph built at a fused level:
        ``ids`` plus the current runtime store tensors."""
        return {"ids": ids, **self.store_runtime_env(params)}

    def use_store(self, store, params: dict) -> dict:
        """Swap the main table's store, converting its param subtree (at
        ``main_embedding_key``) into the new layout (bit-exact — see
        ``EmbeddingStore.adopt``). Returns the updated param tree; the
        model's collection is rebound."""
        self.embedding = FusedEmbeddingCollection(self.spec.embedding_spec(),
                                                  store=store)
        key = self.main_embedding_key
        return {**params, key: store.adopt(params[key])}

    # shared -------------------------------------------------------------------
    def compile(self, params: dict, level: str = "dual",
                batch_size: int = 256, **kwargs):
        """Compile this model into an ``InferencePlan`` (the serving-side
        artifact): ``plan = model.compile(params); plan.predict(ids)``.
        Thin delegation to :func:`repro.core.plan.compile_plan`; serving
        deployments should hold plans (or an ``InferenceEngine``) rather
        than calling :meth:`apply` per request."""
        from repro.core.plan import compile_plan
        return compile_plan(self, params, level, batch_size, **kwargs)

    def apply(self, params: dict, ids: jax.Array) -> jax.Array:
        """Differentiable forward = whole graph in breadth-first order.

        This is the *training* path (traceable under jit/grad). For
        inference use :meth:`compile` / ``InferenceEngine`` — they own
        compiled, batch-shaped artifacts instead of re-executing the graph
        eagerly per call."""
        g = self.build_graph(params, "dual")
        env = g.execute(self.graph_env(params, ids))
        return env["logit"]

    def predict_proba(self, params: dict, ids: jax.Array) -> jax.Array:
        return jax.nn.sigmoid(self.apply(params, ids).reshape(-1))

    def loss(self, params: dict, batch: dict) -> jax.Array:
        return bce_loss(self.apply(params, batch["ids"]), batch["labels"])

    def n_params(self, params: dict) -> int:
        return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
