"""Assigned-architecture LM zoo.

Families: dense GQA decoder, capacity-routed MoE, RWKV6 (attention-free),
Zamba2 (Mamba2 + shared attention), Whisper (enc-dec), Pixtral (VLM).
"""

from .config import LMConfig
from .moe import MoETransformer
from .pixtral import Pixtral
from .rwkv6 import RWKV6
from .transformer import DenseTransformer
from .whisper import Whisper
from .zamba2 import Zamba2

FAMILY_CLASSES = {
    "dense": DenseTransformer,
    "moe": MoETransformer,
    "ssm": RWKV6,
    "hybrid": Zamba2,
    "encdec": Whisper,
    "vlm": Pixtral,
}


def make_lm_model(cfg: LMConfig, shard=None):
    cls = FAMILY_CLASSES[cfg.family]
    from . import layers as L
    return cls(cfg, shard or L.no_shard)


__all__ = ["LMConfig", "DenseTransformer", "MoETransformer", "RWKV6",
           "Zamba2", "Whisper", "Pixtral", "FAMILY_CLASSES", "make_lm_model"]
