"""Dense GQA decoder-only transformer (granite / smollm / llama3 / qwen3 and
the pixtral text backbone).

Layers are stacked (leading L dim) and run under ``lax.scan`` with optional
remat — HLO stays O(1) in depth. All activation placements go through the
injected ``shard`` callable (identity on CPU tests, sharding constraints
under the production mesh).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import layers as L
from .config import LMConfig


class DenseTransformer:
    def __init__(self, cfg: LMConfig, shard: L.Shard = L.no_shard):
        self.cfg = cfg
        self.shard = shard
        # set to a DecodeShardCtx to enable distributed flash-decode
        # (sequence-parallel KV; see layers.flash_decode_sharded)
        self.decode_ctx: L.DecodeShardCtx | None = None
        self.dims = L.AttnDims(
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
            d_model=cfg.d_model, qk_norm=cfg.qk_norm,
            rope_theta=cfg.rope_theta)

    # -- init -----------------------------------------------------------------
    def init_layer(self, key) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        k1, k2 = jax.random.split(key)
        return {
            "ln1": jnp.ones((cfg.d_model,), dtype=dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype=dtype),
            "attn": L.init_attn(k1, self.dims, dtype),
            "mlp": L.init_swiglu(k2, cfg.d_model, cfg.d_ff, dtype),
        }

    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        keys = jax.random.split(key, cfg.n_layers + 2)
        params = {
            "embed": jax.random.normal(
                keys[0], (cfg.vocab, cfg.d_model), dtype=dtype) * 0.02,
            "layers": L.stack_layer_params(
                [self.init_layer(keys[1 + i]) for i in range(cfg.n_layers)]),
            "final_norm": jnp.ones((cfg.d_model,), dtype=dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = jax.random.normal(
                keys[-1], (cfg.d_model, cfg.vocab), dtype=dtype) * 0.02
        return params

    # -- blocks ---------------------------------------------------------------
    def _block(self, x, layer, positions):
        shard = self.shard
        h = L.rms_norm(x, layer["ln1"])
        h = L.attention(layer["attn"], self.dims, h, shard=shard,
                        causal=True, positions=positions)
        x = x + h
        h = L.rms_norm(x, layer["ln2"])
        x = x + self._mlp(layer, h)
        return x

    def _mlp(self, layer, h):
        return L.swiglu(layer["mlp"], h, self.shard)

    def _run_layers(self, params, x, positions):
        cfg = self.cfg

        def step(carry, layer):
            return self._block(carry, layer, positions), None

        if cfg.remat:
            step = jax.checkpoint(step)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(step, x, params["layers"])
        else:
            for i in range(cfg.n_layers):
                layer = jax.tree.map(lambda p: p[i], params["layers"])
                x, _ = step(x, layer)
        return x

    def _head(self, params, x):
        x = L.rms_norm(x, params["final_norm"])
        w = (params["embed"].T if self.cfg.tie_embeddings
             else params["lm_head"])
        logits = x @ w
        return self.shard(logits, ("batch", "seq", "vocab"))

    # -- public ---------------------------------------------------------------
    def embed_tokens(self, params, tokens):
        x = jnp.take(params["embed"], tokens, axis=0)
        return self.shard(x, ("batch", "seq", "embed"))

    def forward(self, params, tokens, positions=None):
        """tokens (b, s) -> logits (b, s, v)."""
        return self.forward_from_x(params, self.embed_tokens(params, tokens),
                                   positions)

    def forward_from_x(self, params, x, positions=None):
        """Pre-embedded entry (VLM/audio frontends inject here)."""
        b, s, _ = x.shape
        if positions is None:
            positions = jnp.arange(s, dtype=jnp.int32)[None, :]
        x = self._run_layers(params, x, positions)
        return self._head(params, x)

    def head_weight(self, params):
        return (params["embed"].T if self.cfg.tie_embeddings
                else params["lm_head"])

    def loss(self, params, batch):
        """Sequence-chunked CE — full (b, s, v) logits never materialize."""
        tokens = batch["tokens"]
        x = self.embed_tokens(params, tokens)
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None, :]
        x = self._run_layers(params, x, positions)
        return L.chunked_ce_loss(x, params["final_norm"],
                                 self.head_weight(params), tokens,
                                 shard=self.shard)

    # -- serving ----------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
        return {
            "k": jnp.zeros(shape, dtype=dtype),
            "v": jnp.zeros(shape, dtype=dtype),
            "index": jnp.zeros((), dtype=jnp.int32),
        }

    def prefill(self, params, tokens, cache):
        """Full-sequence forward that also fills positions [0, s) of the
        cache. Returns (last-position logits (b, v), cache)."""
        return self.prefill_from_x(params,
                                   self.embed_tokens(params, tokens), cache)

    def prefill_from_x(self, params, x, cache):
        cfg = self.cfg
        b, s, _ = x.shape
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]

        def step(carry, layer):
            h = L.rms_norm(carry, layer["ln1"])
            q, k, v = L._qkv(layer["attn"], self.dims, h, positions,
                             self.shard)
            attn = L._attend(q, k, v, causal=True)
            attn = attn.reshape(b, s, cfg.n_heads * cfg.hd) @ layer["attn"]["wo"]
            carry = carry + self.shard(attn, ("batch", "seq", "embed"))
            h = L.rms_norm(carry, layer["ln2"])
            carry = carry + self._mlp(layer, h)
            return carry, (k, v)

        if cfg.remat:
            step = jax.checkpoint(step)
        x, (ks, vs) = jax.lax.scan(step, x, params["layers"])
        logits = self._head(params, x[:, -1:, :])[:, 0]
        s_max = cache["k"].shape[2]
        pad = [(0, 0), (0, 0), (0, s_max - s), (0, 0), (0, 0)]
        cache = {
            "k": jnp.pad(ks, pad).astype(cache["k"].dtype),
            "v": jnp.pad(vs, pad).astype(cache["v"].dtype),
            "index": jnp.asarray(s, dtype=jnp.int32),
        }
        return logits, cache

    def decode_step(self, params, tokens, cache):
        """tokens (b, 1) + cache -> (logits (b, v), updated cache)."""
        cfg = self.cfg
        b = tokens.shape[0]
        idx = cache["index"]
        x = self.embed_tokens(params, tokens)

        def step(carry, xs):
            layer, kc, vc = xs
            h = L.rms_norm(carry, layer["ln1"])
            out, kc, vc = L.attention_decode(
                layer["attn"], self.dims, h, kc, vc, idx, shard=self.shard,
                decode_ctx=self.decode_ctx)
            carry = carry + out
            h = L.rms_norm(carry, layer["ln2"])
            carry = carry + self._mlp(layer, h)
            return carry, (kc, vc)

        x, (ks, vs) = jax.lax.scan(step, x,
                                   (params["layers"], cache["k"], cache["v"]))
        logits = self._head(params, x)[:, 0]
        return logits, {"k": ks, "v": vs, "index": idx + 1}
