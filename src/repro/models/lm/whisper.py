"""Whisper-small backbone — encoder-decoder transformer.

The audio frontend (log-mel + conv downsampling) is a STUB per the
assignment: ``input_specs()`` supplies precomputed frame embeddings
(b, s_enc, d). Encoder: bidirectional MHA + GELU MLP with sinusoidal
positions. Decoder: causal self-attention + cross-attention over the encoded
memory + GELU MLP, learned positions. No RoPE (Whisper uses absolute
positions).

Decode shapes lower the *decoder* step: self-attention KV cache plus
precomputed cross-attention K/V (computed once at prefill from the memory).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from . import layers as L
from .config import LMConfig


def sinusoid_positions(s: int, d: int) -> np.ndarray:
    pos = np.arange(s)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10_000.0, 2 * i / d)
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=-1).astype(np.float32)


class Whisper:
    def __init__(self, cfg: LMConfig, shard: L.Shard = L.no_shard):
        self.cfg = cfg
        self.shard = shard
        self.decode_ctx: L.DecodeShardCtx | None = None
        self.dims = L.AttnDims(
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
            d_model=cfg.d_model)

    # -- init -----------------------------------------------------------------
    def _init_block(self, key, cross: bool) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        ks = jax.random.split(key, 3)
        p = {
            "ln1": jnp.ones((cfg.d_model,), dtype=dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype=dtype),
            "attn": L.init_attn(ks[0], self.dims, dtype),
            "mlp": L.init_gelu_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype),
        }
        if cross:
            p["ln_x"] = jnp.ones((cfg.d_model,), dtype=dtype)
            p["xattn"] = L.init_attn(ks[2], self.dims, dtype)
        return p

    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        n_enc = cfg.encoder_layers
        keys = jax.random.split(key, n_enc + cfg.n_layers + 3)
        return {
            "embed": jax.random.normal(
                keys[0], (cfg.vocab, cfg.d_model), dtype=dtype) * 0.02,
            # sized for the longest assigned decode cell (decode_32k)
            "pos_dec": jax.random.normal(
                keys[1], (65536, cfg.d_model), dtype=dtype) * 0.01,
            "encoder": L.stack_layer_params(
                [self._init_block(keys[2 + i], cross=False)
                 for i in range(n_enc)]),
            "decoder": L.stack_layer_params(
                [self._init_block(keys[2 + n_enc + i], cross=True)
                 for i in range(cfg.n_layers)]),
            "enc_norm": jnp.ones((cfg.d_model,), dtype=dtype),
            "dec_norm": jnp.ones((cfg.d_model,), dtype=dtype),
            "lm_head": jax.random.normal(
                keys[-1], (cfg.d_model, cfg.vocab), dtype=dtype) * 0.02,
        }

    # -- encoder ----------------------------------------------------------------
    def encode(self, params, frames):
        """frames (b, s_enc, d) — stub-frontend output — -> memory."""
        cfg = self.cfg
        b, s, d = frames.shape
        x = frames + jnp.asarray(sinusoid_positions(s, d),
                                 dtype=frames.dtype)[None]
        x = self.shard(x, ("batch", "seq", "embed"))

        def step(carry, layer):
            h = L.rms_norm(carry, layer["ln1"])
            h = L.attention(layer["attn"], self.dims, h, shard=self.shard,
                            causal=False, rope=False)
            carry = carry + h
            h = L.rms_norm(carry, layer["ln2"])
            return carry + L.gelu_mlp(layer["mlp"], h, self.shard), None

        if cfg.remat:
            step = jax.checkpoint(step)
        x, _ = jax.lax.scan(step, x, params["encoder"])
        return L.rms_norm(x, params["enc_norm"])

    # -- decoder ----------------------------------------------------------------
    def _embed_dec(self, params, tokens, pos0=0):
        b, s = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0)
        pos = jax.lax.dynamic_slice_in_dim(params["pos_dec"], pos0, s, axis=0)
        return self.shard(x + pos[None], ("batch", "seq", "embed"))

    def decode_full(self, params, tokens, memory):
        """Teacher-forced decoder (training/prefill math)."""
        cfg = self.cfg
        x = self._embed_dec(params, tokens)

        def step(carry, layer):
            h = L.rms_norm(carry, layer["ln1"])
            h = L.attention(layer["attn"], self.dims, h, shard=self.shard,
                            causal=True, rope=False)
            carry = carry + h
            h = L.rms_norm(carry, layer["ln_x"])
            h = L.attention(layer["xattn"], self.dims, h, shard=self.shard,
                            memory=memory, rope=False)
            carry = carry + h
            h = L.rms_norm(carry, layer["ln2"])
            return carry + L.gelu_mlp(layer["mlp"], h, self.shard), None

        if cfg.remat:
            step = jax.checkpoint(step)
        x, _ = jax.lax.scan(step, x, params["decoder"])
        x = L.rms_norm(x, params["dec_norm"])
        logits = x @ params["lm_head"]
        return self.shard(logits, ("batch", "seq", "vocab"))

    def forward(self, params, tokens, frames):
        return self.decode_full(params, tokens, self.encode(params, frames))

    def loss(self, params, batch):
        memory = self.encode(params, batch["frames"])
        x = self._decoder_hidden(params, batch["tokens"], memory)
        return L.chunked_ce_loss(x, params["dec_norm"], params["lm_head"],
                                 batch["tokens"], shard=self.shard)

    def _decoder_hidden(self, params, tokens, memory):
        cfg = self.cfg
        x = self._embed_dec(params, tokens)

        def step(carry, layer):
            h = L.rms_norm(carry, layer["ln1"])
            h = L.attention(layer["attn"], self.dims, h, shard=self.shard,
                            causal=True, rope=False)
            carry = carry + h
            h = L.rms_norm(carry, layer["ln_x"])
            h = L.attention(layer["xattn"], self.dims, h, shard=self.shard,
                            memory=memory, rope=False)
            carry = carry + h
            h = L.rms_norm(carry, layer["ln2"])
            return carry + L.gelu_mlp(layer["mlp"], h, self.shard), None

        if cfg.remat:
            step = jax.checkpoint(step)
        x, _ = jax.lax.scan(step, x, params["decoder"])
        return x

    # -- serving ----------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, mem_len: int) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        kv = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
        xkv = (cfg.n_layers, batch, mem_len, cfg.n_kv_heads, cfg.hd)
        return {
            "k": jnp.zeros(kv, dtype=dtype),
            "v": jnp.zeros(kv, dtype=dtype),
            "xk": jnp.zeros(xkv, dtype=dtype),
            "xv": jnp.zeros(xkv, dtype=dtype),
            "index": jnp.zeros((), dtype=jnp.int32),
        }

    def prefill(self, params, tokens, frames, cache):
        """Encode + teacher-forced prefix + cache self/cross K/V."""
        cfg = self.cfg
        b, s = tokens.shape
        memory = self.encode(params, frames)
        x = self._embed_dec(params, tokens)
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]
        sm = memory.shape[1]
        h_, kv_, hd = cfg.n_heads, cfg.n_kv_heads, self.dims.head_dim

        def step(carry, layer):
            h = L.rms_norm(carry, layer["ln1"])
            q, k, v = L._qkv(layer["attn"], self.dims, h, positions,
                             self.shard, rope=False)
            attn = L._attend(q, k, v, causal=True)
            carry = carry + attn.reshape(b, s, -1) @ layer["attn"]["wo"]
            h = L.rms_norm(carry, layer["ln_x"])
            qx = (h @ layer["xattn"]["wq"]).reshape(b, s, h_, hd)
            xk = (memory @ layer["xattn"]["wk"]).reshape(b, sm, kv_, hd)
            xv = (memory @ layer["xattn"]["wv"]).reshape(b, sm, kv_, hd)
            attn = L._attend(qx, xk, xv, causal=False)
            carry = carry + attn.reshape(b, s, -1) @ layer["xattn"]["wo"]
            h = L.rms_norm(carry, layer["ln2"])
            return carry + L.gelu_mlp(layer["mlp"], h, self.shard), (k, v, xk, xv)

        x, (ks, vs, xks, xvs) = jax.lax.scan(step, x, params["decoder"])
        x = L.rms_norm(x, params["dec_norm"])
        logits = (x[:, -1:, :] @ params["lm_head"])[:, 0]
        s_max = cache["k"].shape[2]
        pad = [(0, 0), (0, 0), (0, s_max - s), (0, 0), (0, 0)]
        return logits, {
            "k": jnp.pad(ks, pad).astype(cache["k"].dtype),
            "v": jnp.pad(vs, pad).astype(cache["v"].dtype),
            "xk": xks.astype(cache["xk"].dtype),
            "xv": xvs.astype(cache["xv"].dtype),
            "index": jnp.asarray(s, jnp.int32),
        }

    def decode_step(self, params, tokens, cache):
        cfg = self.cfg
        b = tokens.shape[0]
        idx = cache["index"]
        x = jnp.take(params["embed"], tokens, axis=0)
        pos = jax.lax.dynamic_slice_in_dim(params["pos_dec"], idx, 1, axis=0)
        x = x + pos[None]
        h_, hd = cfg.n_heads, self.dims.head_dim

        def step(carry, xs):
            layer, kc, vc, xk, xv = xs
            h = L.rms_norm(carry, layer["ln1"])
            out, kc, vc = L.attention_decode(
                layer["attn"], self.dims, h, kc, vc, idx, shard=self.shard,
                rope=False, decode_ctx=self.decode_ctx)
            carry = carry + out
            h = L.rms_norm(carry, layer["ln_x"])
            qx = (h @ layer["xattn"]["wq"]).reshape(b, 1, h_, hd)
            if self.decode_ctx is not None:
                # cross-attention over the seq-sharded encoded memory
                limit = jnp.asarray(xk.shape[1] + 1, jnp.int32)
                attn, _, _ = L.flash_decode_sharded(
                    qx, xk, xv, None, None, limit, self.decode_ctx)
            else:
                attn = L._attend(qx, xk, xv, causal=False)
            carry = carry + attn.reshape(b, 1, -1) @ layer["xattn"]["wo"]
            h = L.rms_norm(carry, layer["ln2"])
            return carry + L.gelu_mlp(layer["mlp"], h, self.shard), (kc, vc)

        x, (ks, vs) = jax.lax.scan(
            step, x, (params["decoder"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]))
        x = L.rms_norm(x, params["dec_norm"])
        logits = (x @ params["lm_head"])[:, 0]
        return logits, {**cache, "k": ks, "v": vs, "index": idx + 1}
