"""LMConfig — one static description shared by every assigned architecture."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None    # defaults to d_model // n_heads
    qk_norm: bool = False
    tie_embeddings: bool = False
    # --- moe ---
    n_experts: int = 0
    top_k: int = 1
    capacity_factor: float = 1.25
    # H3: replicate dispatched token buffers over the data axis instead
    # of gathering d-sharded expert weights (right when weights >> tokens)
    moe_token_replicate: bool = False
    # --- ssm / hybrid ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    conv_kernel: int = 4
    shared_attn_every: int = 0     # zamba2: one shared attn block per N layers
    # --- enc-dec ---
    encoder_layers: int = 0
    # --- vlm ---
    patch_frontend: bool = False
    # --- numerics / compile ---
    dtype: str = "bfloat16"
    rope_theta: float = 10_000.0
    remat: bool = True
    scan_layers: bool = True
    # attention flavour: "full" | "none" (ssm) — long_500k eligibility
    attention: str = "full"

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else (
            self.d_model // self.n_heads)

    def reduced(self, **overrides) -> "LMConfig":
        """Tiny same-family variant for CPU smoke tests."""
        base = dict(
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=2 if self.n_kv_heads < self.n_heads else 4,
            d_ff=128,
            vocab=256,
            head_dim=16 if self.head_dim is not None else None,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            shared_attn_every=2 if self.shared_attn_every else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            dtype="float32",
            remat=False,
        )
        base.update(overrides)
        return dataclasses.replace(self, **base)
