"""RWKV6 "Finch" — attention-free RNN with data-dependent decay (rwkv6-7b).

Faithful structure: token-shift lerps, r/k/v/g projections, per-channel
data-dependent decay w_t = exp(−exp(w_base + LoRA(x))) and the bonus-u WKV
recurrence  S_t = diag(w_t)·S_{t−1} + k_tᵀ v_t,  o_t = r_t·(S_{t−1} + u∘k_tᵀ v_t),
plus the squared-ReLU channel-mix. The recurrence is a ``lax.scan`` over
time (one HLO while-loop — the production TPU form would be the chunked
parallel scan; see EXPERIMENTS §Perf for the chunked variant).

Decode state is O(1) in sequence length — this is why rwkv6 runs the
``long_500k`` cell that dense-attention archs skip.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from . import layers as L
from .config import LMConfig

LORA_R = 32


def _init_linear(key, d_in, d_out, dtype):
    return jax.random.normal(key, (d_in, d_out), dtype=dtype) * float(1.0 / np.sqrt(d_in))


class RWKV6:
    def __init__(self, cfg: LMConfig, shard: L.Shard = L.no_shard):
        self.cfg = cfg
        self.shard = shard
        self.hd = cfg.ssm_head_dim
        self.n_heads_tm = cfg.d_model // self.hd

    # -- init -----------------------------------------------------------------
    def init_layer(self, key) -> dict:
        cfg = self.cfg
        d, f = cfg.d_model, cfg.d_ff
        dtype = jnp.dtype(cfg.dtype)
        ks = jax.random.split(key, 10)
        h, hd = self.n_heads_tm, self.hd
        return {
            "ln1": jnp.ones((d,), dtype=dtype),
            "ln2": jnp.ones((d,), dtype=dtype),
            "mu": 0.5 * jnp.ones((5, d), dtype=dtype),      # r,k,v,g,w shifts
            "wr": _init_linear(ks[0], d, d, dtype),
            "wk": _init_linear(ks[1], d, d, dtype),
            "wv": _init_linear(ks[2], d, d, dtype),
            "wg": _init_linear(ks[3], d, d, dtype),
            "wo": _init_linear(ks[4], d, d, dtype),
            "w_base": jnp.full((d,), -2.0, dtype=dtype),
            "w_lora_a": _init_linear(ks[5], d, LORA_R, dtype),
            "w_lora_b": jnp.zeros((LORA_R, d), dtype=dtype),
            "u": jnp.zeros((h, hd), dtype=dtype),
            "ln_x": jnp.ones((d,), dtype=dtype),             # post-wkv norm
            "mu_c": 0.5 * jnp.ones((2, d), dtype=dtype),     # channel-mix k,r
            "wck": _init_linear(ks[6], d, f, dtype),
            "wcv": _init_linear(ks[7], f, d, dtype),
            "wcr": _init_linear(ks[8], d, d, dtype),
        }

    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        keys = jax.random.split(key, cfg.n_layers + 2)
        return {
            "embed": jax.random.normal(
                keys[0], (cfg.vocab, cfg.d_model), dtype=dtype) * 0.02,
            "layers": L.stack_layer_params(
                [self.init_layer(keys[1 + i]) for i in range(cfg.n_layers)]),
            "final_norm": jnp.ones((cfg.d_model,), dtype=dtype),
            "lm_head": jax.random.normal(
                keys[-1], (cfg.d_model, cfg.vocab), dtype=dtype) * 0.02,
        }

    # -- pieces ---------------------------------------------------------------
    def _decay(self, layer, xw):
        """Data-dependent per-channel decay in (0, 1)."""
        lo = jnp.tanh(xw @ layer["w_lora_a"]) @ layer["w_lora_b"]
        return jnp.exp(-jnp.exp(
            (layer["w_base"] + lo).astype(jnp.float32)))

    def _wkv_scan(self, r, k, v, w, u, state):
        """Recurrence over time.

        r/k/v/w: (b, s, h, hd); u: (h, hd); state: (b, h, hd, hd).
        Returns (out (b, s, h, hd), final state).
        """
        def step(S, inp):
            r_t, k_t, v_t, w_t = inp                    # (b, h, hd) each
            kv = k_t[..., :, None] * v_t[..., None, :]  # (b, h, hd, hd)
            o = jnp.einsum("bhi,bhij->bhj", r_t,
                           S + u[None, :, :, None] * kv)
            S = w_t[..., :, None] * S + kv
            return S, o

        xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
        state, out = jax.lax.scan(step, state, xs)
        return jnp.moveaxis(out, 0, 1), state

    def _time_mix(self, layer, x, x_prev, state):
        """x (b, s, d); x_prev (b, d) last token of the previous segment."""
        b, s, d = x.shape
        h, hd = self.n_heads_tm, self.hd
        xs = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
        mu = layer["mu"]
        mix = lambda i: x + mu[i] * (xs - x)
        xr, xk, xv, xg, xw = (mix(i) for i in range(5))
        r = (xr @ layer["wr"]).reshape(b, s, h, hd)
        k = (xk @ layer["wk"]).reshape(b, s, h, hd)
        v = (xv @ layer["wv"]).reshape(b, s, h, hd)
        g = xg @ layer["wg"]
        w = self._decay(layer, xw).reshape(b, s, h, hd).astype(x.dtype)
        out, state = self._wkv_scan(r, k, v, w, layer["u"], state)
        out = out.reshape(b, s, d).astype(x.dtype)   # state math stays f32
        out = L.rms_norm(out, layer["ln_x"])
        out = (out * jax.nn.silu(g)) @ layer["wo"]
        return self.shard(out, ("batch", "seq", "embed")), x[:, -1, :], state

    def _channel_mix(self, layer, x, x_prev):
        xs = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
        mu = layer["mu_c"]
        xk = x + mu[0] * (xs - x)
        xr = x + mu[1] * (xs - x)
        kk = jnp.square(jax.nn.relu(xk @ layer["wck"]))
        kk = self.shard(kk, ("batch", "seq", "mlp"))
        out = jax.nn.sigmoid(xr @ layer["wcr"]) * (kk @ layer["wcv"])
        return self.shard(out, ("batch", "seq", "embed")), x[:, -1, :]

    def _block(self, layer, x, st):
        h1, tm_prev, tm_state = self._time_mix(
            layer, L.rms_norm(x, layer["ln1"]), st["tm_prev"], st["tm_state"])
        x = x + h1
        h2, cm_prev = self._channel_mix(
            layer, L.rms_norm(x, layer["ln2"]), st["cm_prev"])
        x = x + h2
        return x, {"tm_prev": tm_prev, "tm_state": tm_state,
                   "cm_prev": cm_prev}

    def _zero_state(self, b):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        h, hd = self.n_heads_tm, self.hd
        return {
            "tm_prev": jnp.zeros((b, cfg.d_model), dtype=dtype),
            "tm_state": jnp.zeros((b, h, hd, hd), dtype=jnp.float32),
            "cm_prev": jnp.zeros((b, cfg.d_model), dtype=dtype),
        }

    # -- public ---------------------------------------------------------------
    def forward(self, params, tokens, state=None, return_state=False):
        cfg = self.cfg
        b, s = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0)
        x = self.shard(x, ("batch", "seq", "embed"))

        def layer_step(carry, xs):
            layer, st = xs
            out, st = self._block(layer, carry, st)
            return out, st

        if cfg.remat:
            layer_step = jax.checkpoint(layer_step)
        if state is None:
            states = jax.tree.map(
                lambda z: jnp.broadcast_to(z, (cfg.n_layers,) + z.shape),
                self._zero_state(b))
        else:
            states = state
        x, states = jax.lax.scan(layer_step, x, (params["layers"], states))
        x = L.rms_norm(x, params["final_norm"])
        logits = x @ params["lm_head"]
        logits = self.shard(logits, ("batch", "seq", "vocab"))
        if return_state:
            return logits, states
        return logits

    def hidden(self, params, tokens, state=None):
        """Final hidden states (pre-norm, pre-head)."""
        cfg = self.cfg
        b, s = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0)
        x = self.shard(x, ("batch", "seq", "embed"))

        def layer_step(carry, xs):
            layer, st = xs
            out, st = self._block(layer, carry, st)
            return out, st

        if cfg.remat:
            layer_step = jax.checkpoint(layer_step)
        if state is None:
            state = jax.tree.map(
                lambda z: jnp.broadcast_to(z, (cfg.n_layers,) + z.shape),
                self._zero_state(b))
        x, states = jax.lax.scan(layer_step, x, (params["layers"], state))
        return x, states

    def loss(self, params, batch):
        x, _ = self.hidden(params, batch["tokens"])
        return L.chunked_ce_loss(x, params["final_norm"],
                                 params["lm_head"], batch["tokens"],
                                 shard=self.shard)

    # -- serving ----------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> dict:
        del max_len  # O(1) state!
        return jax.tree.map(
            lambda z: jnp.broadcast_to(z, (self.cfg.n_layers,) + z.shape)
                      .copy(),
            self._zero_state(batch))

    def prefill(self, params, tokens, cache):
        logits, state = self.forward(params, tokens, state=cache,
                                     return_state=True)
        return logits[:, -1], state

    def decode_step(self, params, tokens, cache):
        logits, state = self.forward(params, tokens, state=cache,
                                     return_state=True)
        return logits[:, 0], state
