"""Mixture-of-Experts transformer (phi3.5-moe 16e top-2, llama4 128e top-1).

Capacity-based token dispatch in the grouped-einsum formulation (Flaxformer
style): tokens are grouped by batch row; each group independently routes to
experts with capacity C = ceil(s·k·capacity_factor / E). Dispatch/combine
are one-hot einsums, which GSPMD turns into the EP all-to-all when experts
are sharded over the ``model`` axis and tokens over ``data`` — the paper's
inter-module parallelism (C1/C4) maps onto exactly this overlap (DESIGN §4).

Dropped tokens (over capacity) fall through the residual connection — the
standard behaviour. An auxiliary load-balancing loss is returned alongside.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from . import layers as L
from .config import LMConfig
from .transformer import DenseTransformer


def init_moe_ffn(key, cfg: LMConfig, dtype) -> dict:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    s_in, s_out = float(1.0 / np.sqrt(d)), float(1.0 / np.sqrt(f))
    return {
        "router": jax.random.normal(ks[0], (d, e), dtype=jnp.float32) * s_in,
        "w_gate": jax.random.normal(ks[1], (e, d, f), dtype=dtype) * s_in,
        "w_up": jax.random.normal(ks[2], (e, d, f), dtype=dtype) * s_in,
        "w_down": jax.random.normal(ks[3], (e, f, d), dtype=dtype) * s_out,
    }


def capacity(cfg: LMConfig, tokens_per_group: int) -> int:
    c = int(np.ceil(tokens_per_group * cfg.top_k * cfg.capacity_factor
                    / cfg.n_experts))
    return max(c, 1)


GROUP_SIZE = 512      # routing-group length: caps capacity buffers (M5)


def moe_ffn(p: dict, x: jax.Array, cfg: LMConfig,
            shard: L.Shard = L.no_shard) -> tuple[jax.Array, jax.Array]:
    """x (b, s, d) -> (out (b, s, d), aux_loss scalar).

    Tokens are regrouped into GROUP_SIZE-token routing groups (independent
    capacity buffers per group), which bounds the (g, e, c) one-hot tensors
    regardless of sequence length. Router runs in fp32.

    Distribution (H3): the token-vs-weight movement choice is per-arch —
    ``cfg.moe_token_replicate=True`` (llama4: 800 GB of experts) keeps
    expert weights fully sharded and replicates the dispatched token
    buffers over the data axis (tokens ≪ weights); phi3.5-scale MoE keeps
    token buffers data-sharded and lets the d-sharded expert weights gather
    (weights ≪ tokens·k). Measured in EXPERIMENTS §Perf.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    # groups are cut from the flattened token stream: at decode (s == 1)
    # all tokens route in ONE group, otherwise per-group capacity padding
    # (c >= 1 per expert per group) over-computes by up to E/k ×
    gsz = min(GROUP_SIZE, b * s)
    ng = (b * s) // gsz
    c = capacity(cfg, gsz)
    dtype = x.dtype
    xg = x.reshape(ng, gsz, d)

    gate_logits = xg.astype(jnp.float32) @ p["router"]          # (G, g, e)
    probs = jax.nn.softmax(gate_logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, k)                 # (G, g, k)

    # position of each (token, slot) inside its expert's capacity buffer
    expert_mask = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)  # (G, g, k, e)
    flat_mask = expert_mask.reshape(ng, gsz * k, e)
    pos = jnp.cumsum(flat_mask, axis=1) * flat_mask - 1.0
    pos = pos.reshape(ng, gsz, k, e)
    keep = (pos >= 0) & (pos < c)
    pos = jnp.where(keep, pos, 0).astype(jnp.int32)

    cap_oh = jax.nn.one_hot(pos, c, dtype=jnp.float32)           # (G, g, k, e, c)
    cap_oh = cap_oh * keep[..., None].astype(jnp.float32)
    dispatch = jnp.sum(cap_oh, axis=2).astype(dtype)             # (G, g, e, c)
    combine = jnp.sum(cap_oh * top_vals[..., None, None], axis=2)
    combine = combine.astype(dtype)
    dispatch = shard(dispatch, ("batch", None, "experts", None))
    combine = shard(combine, ("batch", None, "experts", None))

    xin = jnp.einsum("gsec,gsd->gecd", dispatch, xg)             # (G, e, c, d)
    tok_axis = None if cfg.moe_token_replicate else "batch"
    xin = shard(xin, (tok_axis, "experts", None, None))
    g_ = jnp.einsum("gecd,edf->gecf", xin, p["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", xin, p["w_up"])
    h = jax.nn.silu(g_) * u
    h = shard(h, (tok_axis, "experts", None, "expert_mlp"))
    eo = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    eo = shard(eo, (tok_axis, "experts", None, None))
    out = jnp.einsum("gsec,gecd->gsd", combine, eo)
    out = shard(out.reshape(b, s, d), ("batch", "seq", "embed"))

    # load-balancing auxiliary loss (Switch-style)
    frac_tokens = jnp.mean(expert_mask.sum(axis=2), axis=(0, 1))   # (e,)
    frac_probs = jnp.mean(probs, axis=(0, 1))                      # (e,)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return out, aux


class MoETransformer(DenseTransformer):
    """DenseTransformer with the FFN swapped for capacity-routed experts."""

    def init_layer(self, key) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        k1, k2 = jax.random.split(key)
        return {
            "ln1": jnp.ones((cfg.d_model,), dtype=dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype=dtype),
            "attn": L.init_attn(k1, self.dims, dtype),
            "moe": init_moe_ffn(k2, cfg, dtype),
        }

    def _mlp(self, layer, h):
        out, _aux = moe_ffn(layer["moe"], h, self.cfg, self.shard)
        return out

    def loss(self, params, batch, aux_weight: float = 0.01):
        """Next-token loss + router load-balancing aux term."""
        cfg = self.cfg
        b, s = batch["tokens"].shape
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]
        x = self.embed_tokens(params, batch["tokens"])

        def step(carry, layer):
            h = L.rms_norm(carry, layer["ln1"])
            h = L.attention(layer["attn"], self.dims, h, shard=self.shard,
                            causal=True, positions=positions)
            carry = carry + h
            h = L.rms_norm(carry, layer["ln2"])
            out, aux = moe_ffn(layer["moe"], h, self.cfg, self.shard)
            return carry + out, aux

        step_fn = jax.checkpoint(step) if cfg.remat else step
        if cfg.scan_layers:
            x, auxes = jax.lax.scan(step_fn, x, params["layers"])
            aux = jnp.mean(auxes)
        else:
            aux = 0.0
            for i in range(cfg.n_layers):
                layer = jax.tree.map(lambda p: p[i], params["layers"])
                x, a = step_fn(x, layer)
                aux += a / cfg.n_layers
        ce = L.chunked_ce_loss(x, params["final_norm"],
                               self.head_weight(params), batch["tokens"],
                               shard=self.shard)
        return ce + aux_weight * aux
