"""Pixtral-12B backbone — mistral-nemo-style decoder with a vision-token
prefix. The Pixtral ViT frontend is a STUB per the assignment:
``input_specs()`` supplies precomputed patch embeddings (b, s_img, d_model)
which are concatenated ahead of the text embeddings; everything downstream
is the dense GQA decoder (explicit head_dim=128 ≠ d_model/n_heads, as in
mistral-nemo).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from .config import LMConfig
from .transformer import DenseTransformer


class Pixtral(DenseTransformer):
    """DenseTransformer consuming [patch_embeds; text tokens]."""

    def fuse_inputs(self, params, tokens, patch_embeds):
        """(b, s_txt) tokens + (b, s_img, d) patches -> (b, s_img+s_txt, d)."""
        tx = self.embed_tokens(params, tokens)
        x = jnp.concatenate([patch_embeds.astype(tx.dtype), tx], axis=1)
        return self.shard(x, ("batch", "seq", "embed"))

    def forward(self, params, tokens, patch_embeds=None, positions=None):
        if patch_embeds is None:
            return super().forward(params, tokens, positions)
        x = self.fuse_inputs(params, tokens, patch_embeds)
        return self.forward_from_x(params, x, positions)

    def loss(self, params, batch):
        """Sequence-chunked next-token loss on the text region only."""
        pe = batch.get("patch_embeds")
        if pe is None:
            return super().loss(params, batch)
        x = self.fuse_inputs(params, batch["tokens"], pe)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
        x = self._run_layers(params, x, positions)
        x_txt = x[:, pe.shape[1]:]
        return L.chunked_ce_loss(x_txt, params["final_norm"],
                                 self.head_weight(params), batch["tokens"],
                                 shard=self.shard)

    def prefill(self, params, tokens, cache, patch_embeds=None):
        if patch_embeds is None:
            return super().prefill(params, tokens, cache)
        x = self.fuse_inputs(params, tokens, patch_embeds)
        return self.prefill_from_x(params, x, cache)
    # decode_step: inherited — text tokens decode against the joint cache.
