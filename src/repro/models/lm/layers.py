"""Shared transformer building blocks for the assigned-architecture zoo.

Everything is functional (params-in, activations-out) and scan-friendly:
per-layer parameter leaves carry a leading L dimension and blocks are run
under ``jax.lax.scan`` with a configurable remat policy (MaxText-style),
which keeps HLO size O(1) in depth — essential for 40-cell dry-run compiles.

Sharding is injected, not global: every function takes ``shard``, a callable
``(x, logical_axes) -> x`` that the launcher binds to
``with_sharding_constraint`` through the logical-axis rules in
``repro.distributed.sharding``; CPU unit tests bind identity.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np
import jax
import jax.numpy as jnp

from repro.compat import shard_map

Shard = Callable[[jax.Array, tuple[str | None, ...]], jax.Array]


def no_shard(x: jax.Array, logical_axes: tuple[str | None, ...]) -> jax.Array:
    return x


# ---------------------------------------------------------------------------
# norms & rotary
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * gamma.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float = 10_000.0) -> jax.Array:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10_000.0) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))                  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                                     # (..., S, 1, hd/2)
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, optional qk-norm) — full / causal / cached-decode
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnDims:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_model: int
    qk_norm: bool = False
    rope_theta: float = 10_000.0


def init_attn(key, dims: AttnDims, dtype) -> dict:
    d, h, kv, hd = dims.d_model, dims.n_heads, dims.n_kv_heads, dims.head_dim
    ks = jax.random.split(key, 4)
    s = float(1.0 / np.sqrt(d))
    p = {
        "wq": jax.random.normal(ks[0], (d, h * hd), dtype=dtype) * s,
        "wk": jax.random.normal(ks[1], (d, kv * hd), dtype=dtype) * s,
        "wv": jax.random.normal(ks[2], (d, kv * hd), dtype=dtype) * s,
        "wo": jax.random.normal(ks[3], (h * hd, d), dtype=dtype)
              * float(1.0 / np.sqrt(h * hd)),
    }
    if dims.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype=dtype)
        p["k_norm"] = jnp.ones((hd,), dtype=dtype)
    return p


def _qkv(p: dict, dims: AttnDims, x: jax.Array, positions: jax.Array,
         shard: Shard, rope: bool = True):
    b, s, _ = x.shape
    h, kv, hd = dims.n_heads, dims.n_kv_heads, dims.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (x @ p["wk"]).reshape(b, s, kv, hd)
    v = (x @ p["wv"]).reshape(b, s, kv, hd)
    # q is head-sharded over the model axis; k/v keep kv heads unsharded
    # (GQA TP > kv_heads would force uneven splits / involuntary remats —
    # the repeat-to-h below lets GSPMD slice the broadcast per shard).
    q = shard(q, ("batch", "seq", "heads", None))
    if dims.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if rope:
        q = apply_rope(q, positions, dims.rope_theta)
        k = apply_rope(k, positions, dims.rope_theta)
    return q, k, v


def _expand_gqa(k, h):
    """(b, s, kv, hd) -> (b, s, h, hd) by group broadcast (fused by XLA)."""
    kv = k.shape[2]
    if kv == h:
        return k
    return jnp.repeat(k, h // kv, axis=2)


def _sdpa(q, k, v, *, causal: bool, q_pos=None, k_pos=None):
    """q: (b, sq, h, hd); k/v: (b, sk, kv, hd) — GQA via head repeat."""
    b, sq, h, hd = q.shape
    _, sk, kv, _ = k.shape
    k = _expand_gqa(k, h)
    v = _expand_gqa(v, h)
    scale = float(1.0 / np.sqrt(hd))
    logits = jnp.einsum("bqhd,bshd->bhqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        qp = (jnp.arange(sq) if q_pos is None else q_pos)
        kp = (jnp.arange(sk) if k_pos is None else k_pos)
        mask = qp[:, None] >= kp[None, :]
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", probs, v)


# sequences at or above this length use chunked online-softmax attention
# (direct attention would materialize an s×s score tensor: at 4k×4k×f32 and
# 2 heads/chip × 16 samples that alone is ~4GiB — §Perf iteration M1)
FLASH_THRESHOLD = 4096
FLASH_CHUNK = 1024


def _attend(q, k, v, *, causal: bool):
    if q.shape[1] >= FLASH_THRESHOLD or k.shape[1] >= FLASH_THRESHOLD:
        return flash_attention(q, k, v, causal=causal,
                               q_chunk=FLASH_CHUNK, k_chunk=FLASH_CHUNK)
    return _sdpa(q, k, v, causal=causal)


def attention(p: dict, dims: AttnDims, x: jax.Array, *,
              shard: Shard = no_shard, causal: bool = True,
              positions: jax.Array | None = None,
              memory: jax.Array | None = None,
              rope: bool = True) -> jax.Array:
    """Full (train/prefill) attention; ``memory`` switches to cross-attn."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :].astype(jnp.int32)
    if memory is None:
        q, k, v = _qkv(p, dims, x, positions, shard, rope)
    else:
        # cross attention: q from x, k/v from memory (no rope on memory)
        h, kv, hd = dims.n_heads, dims.n_kv_heads, dims.head_dim
        sm = memory.shape[1]
        q = (x @ p["wq"]).reshape(b, s, h, hd)
        k = (memory @ p["wk"]).reshape(b, sm, kv, hd)
        v = (memory @ p["wv"]).reshape(b, sm, kv, hd)
        causal = False
    out = _attend(q, k, v, causal=causal)
    out = out.reshape(b, s, dims.n_heads * dims.head_dim)
    out = out @ p["wo"]
    return shard(out, ("batch", "seq", "embed"))


@dataclasses.dataclass(frozen=True)
class DecodeShardCtx:
    """Distributed flash-decode context (sequence-parallel KV).

    Without it, GSPMD resolves attention over a seq-sharded KV cache by
    ALL-GATHERING the cache per layer (measured 65.75 GiB/device/step on
    llama3-8b decode_32k — EXPERIMENTS §Perf H1-baseline). With it, each
    model-axis shard attends over its local sequence slice and the partial
    softmax states (running max / denominator / weighted value) are combined
    with three tiny psums — the flash-decoding scheme, made explicit via
    shard_map so the partitioner cannot choose the gather.
    """
    mesh: object
    batch_axes: tuple | None       # None = batch unsharded (e.g. b == 1)
    seq_axis: str = "model"


def flash_decode_sharded(q, k_cache, v_cache, k_new, v_new, cache_index,
                         ctx: DecodeShardCtx):
    """One-token attention over a sequence-sharded KV cache + in-place
    (shard-local) cache update at ``cache_index``.

    q (b, 1, h, hd); caches (b, S, kv, hd) sharded (batch, seq_axis, -, -).
    Returns (out (b, 1, h, hd), k_cache, v_cache).
    """
    from jax.sharding import PartitionSpec as P

    ax = ctx.seq_axis
    b_ax = ctx.batch_axes

    update = k_new is not None

    def local(q, kc, vc, kn, vn, idx):
        s_local = kc.shape[1]
        shard_id = jax.lax.axis_index(ax)
        start = shard_id * s_local
        if update:
            li = idx - start
            in_range = (li >= 0) & (li < s_local)
            safe = jnp.clip(li, 0, s_local - 1)
            kc_u = jax.lax.dynamic_update_slice_in_dim(kc, kn, safe, axis=1)
            vc_u = jax.lax.dynamic_update_slice_in_dim(vc, vn, safe, axis=1)
            kc = jnp.where(in_range, kc_u, kc)
            vc = jnp.where(in_range, vc_u, vc)
        h = q.shape[2]
        ke = _expand_gqa(kc, h)
        ve = _expand_gqa(vc, h)
        scale = float(1.0 / np.sqrt(q.shape[-1]))
        logits = jnp.einsum("bqhd,bshd->bhqs", q, ke,
                            preferred_element_type=jnp.float32) * scale
        kpos = start + jnp.arange(s_local)
        valid = kpos <= idx
        logits = jnp.where(valid[None, None, None, :], logits, -1e30)
        m_loc = jnp.max(logits, axis=-1)                       # (b,h,1)
        m = jax.lax.pmax(m_loc, ax)
        p = jnp.exp(logits - m[..., None])
        l = jax.lax.psum(jnp.sum(p, axis=-1), ax)              # (b,h,1)
        o = jax.lax.psum(
            jnp.einsum("bhqs,bshd->bqhd", p.astype(ve.dtype), ve), ax)
        out = o / jnp.maximum(l[:, None], 1e-30).astype(o.dtype)  # (b,1,h,1)
        return out, kc, vc

    cache_spec = P(b_ax, ax, None, None)
    q_spec = P(b_ax, None, None, None)
    if not update:
        k_new = jnp.zeros_like(q[:, :, :1])
        v_new = jnp.zeros_like(q[:, :, :1])
    fn = shard_map(local, mesh=ctx.mesh,
                   in_specs=(q_spec, cache_spec, cache_spec,
                             q_spec, q_spec, P()),
                   out_specs=(q_spec, cache_spec, cache_spec),
                   check_vma=False)
    return fn(q, k_cache, v_cache, k_new, v_new, cache_index)


def attention_decode(p: dict, dims: AttnDims, x: jax.Array,
                     k_cache: jax.Array, v_cache: jax.Array,
                     cache_index: jax.Array, *,
                     shard: Shard = no_shard, rope: bool = True,
                     decode_ctx: "DecodeShardCtx | None" = None):
    """One-token decode against a (b, S_max, kv, hd) KV cache.

    Returns (out (b, 1, d), k_cache, v_cache) with the caches updated at
    ``cache_index``. Masking is positional: cache slots ≥ cache_index+1 are
    excluded, so pre-zeroed caches need no validity bitmap.
    """
    b = x.shape[0]
    positions = jnp.full((b, 1), cache_index, dtype=jnp.int32)
    q, k, v = _qkv(p, dims, x, positions, shard, rope)
    if decode_ctx is not None:
        out, k_cache, v_cache = flash_decode_sharded(
            q, k_cache, v_cache, k, v, cache_index, decode_ctx)
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k, cache_index, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v, cache_index, axis=1)
        s_max = k_cache.shape[1]
        kpos = jnp.arange(s_max)
        valid = (kpos <= cache_index)[None, :]               # (1, S_max)
        out = _sdpa_decode(q, k_cache, v_cache, valid)
    out = out.reshape(b, 1, dims.n_heads * dims.head_dim) @ p["wo"]
    return shard(out, ("batch", "seq", "embed")), k_cache, v_cache


def _sdpa_decode(q, k, v, valid):
    b, sq, h, hd = q.shape
    k = _expand_gqa(k, h)
    v = _expand_gqa(v, h)
    scale = float(1.0 / np.sqrt(hd))
    logits = jnp.einsum("bqhd,bshd->bhqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", probs, v)


# ---------------------------------------------------------------------------
# chunked (online-softmax / "flash") attention — memory-feasible long-context
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool, q_chunk: int = 1024,
                    k_chunk: int = 1024) -> jax.Array:
    """Exact attention with O(s·chunk) memory via online softmax.

    q (b, sq, h, hd); k/v (b, sk, kv, hd). Pure-jnp reference form (the
    Pallas kernel variant lives in repro.kernels.flash_attention); the
    k-chunk loop is a lax.scan, so HLO cost_analysis counts its body once —
    the roofline analyzer corrects analytically (DESIGN §Roofline).
    """
    b, sq, h, hd = q.shape
    _, sk, kv, _ = k.shape
    k = _expand_gqa(k, h)
    v = _expand_gqa(v, h)
    qc = min(q_chunk, sq)
    kc = min(k_chunk, sk)
    nq, nk = sq // qc, sk // kc
    assert sq % qc == 0 and sk % kc == 0, "seq must divide chunk"
    scale = float(1.0 / np.sqrt(hd))

    qr = q.reshape(b, nq, qc, h, hd)
    kr = k.reshape(b, nk, kc, h, hd)
    vr = v.reshape(b, nk, kc, h, hd)

    def q_block(qi, q_blk):
        # q_blk: (b, qc, h, hd)
        m0 = jnp.full((b, h, qc), -jnp.inf, dtype=jnp.float32)
        l0 = jnp.zeros((b, h, qc), dtype=jnp.float32)
        a0 = jnp.zeros((b, h, qc, hd), dtype=jnp.float32)

        @jax.checkpoint
        def k_block(carry, inp):
            m, l, acc = carry
            ki, k_blk, v_blk = inp
            logits = jnp.einsum("bqhd,bshd->bhqs", q_blk, k_blk,
                                preferred_element_type=jnp.float32) * scale
            if causal:
                qpos = qi * qc + jnp.arange(qc)
                kpos = ki * kc + jnp.arange(kc)
                mask = qpos[:, None] >= kpos[None, :]
                logits = jnp.where(mask[None, None], logits, -1e30)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l = l * corr + p.sum(axis=-1)
            acc = (acc * corr[..., None]
                   + jnp.einsum("bhqs,bshd->bhqd", p,
                                v_blk.astype(jnp.float32)))
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(
            k_block, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kr, 1, 0), jnp.moveaxis(vr, 1, 0)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.transpose(out, (0, 2, 1, 3))           # (b, qc, h, hd)

    out = jax.lax.map(lambda inp: q_block(inp[0], inp[1]),
                      (jnp.arange(nq), jnp.moveaxis(qr, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_swiglu(key, d_model: int, d_ff: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    s_in, s_out = float(1.0 / np.sqrt(d_model)), float(1.0 / np.sqrt(d_ff))
    return {
        "w_gate": jax.random.normal(ks[0], (d_model, d_ff), dtype=dtype) * s_in,
        "w_up": jax.random.normal(ks[1], (d_model, d_ff), dtype=dtype) * s_in,
        "w_down": jax.random.normal(ks[2], (d_ff, d_model), dtype=dtype) * s_out,
    }


def swiglu(p: dict, x: jax.Array, shard: Shard = no_shard) -> jax.Array:
    g = x @ p["w_gate"]
    u = x @ p["w_up"]
    h = jax.nn.silu(g) * u
    h = shard(h, ("batch", "seq", "mlp"))
    out = h @ p["w_down"]
    return shard(out, ("batch", "seq", "embed"))


def init_gelu_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "w_in": jax.random.normal(ks[0], (d_model, d_ff), dtype=dtype)
                * float(1.0 / np.sqrt(d_model)),
        "b_in": jnp.zeros((d_ff,), dtype=dtype),
        "w_out": jax.random.normal(ks[1], (d_ff, d_model), dtype=dtype)
                 * float(1.0 / np.sqrt(d_ff)),
        "b_out": jnp.zeros((d_model,), dtype=dtype),
    }


def gelu_mlp(p: dict, x: jax.Array, shard: Shard = no_shard) -> jax.Array:
    h = jax.nn.gelu(x @ p["w_in"] + p["b_in"])
    h = shard(h, ("batch", "seq", "mlp"))
    return shard(h @ p["w_out"] + p["b_out"], ("batch", "seq", "embed"))


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def next_token_loss(logits: jax.Array, tokens: jax.Array) -> jax.Array:
    """Shifted cross entropy; logits (b, s, v), tokens (b, s)."""
    logits = logits[:, :-1].astype(jnp.float32)
    targets = tokens[:, 1:]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None],
                              axis=-1)[..., 0]
    return jnp.mean(logz - tgt)


def chunked_ce_loss(x: jax.Array, gamma: jax.Array, w_head: jax.Array,
                    tokens: jax.Array, *, chunk: int = 1024,
                    shard: Shard = no_shard) -> jax.Array:
    """Next-token CE directly from final hidden states, sequence-chunked so
    the (b, s, vocab) f32 logits tensor is never materialized (§Perf M2).

    The chunk loop is a *python* loop (unrolled HLO): exact cost_analysis
    accounting and still O(s/chunk) live memory.
    """
    b, s, d = x.shape
    s_eff = s - 1                              # last position has no target
    chunk = min(chunk, s_eff)

    @jax.checkpoint
    def chunk_loss(xc, targets):
        # rematerialized in the backward: the (b, chunk, vocab) f32 softmax
        # residuals never accumulate across chunks (§Perf M4)
        xc = rms_norm(xc, gamma)
        logits = (xc @ w_head).astype(jnp.float32)
        logits = shard(logits, ("batch", "seq", "vocab"))
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, targets[..., None],
                                  axis=-1)[..., 0]
        return jnp.sum(logz - tgt)

    total = jnp.zeros((), dtype=jnp.float32)
    for lo in range(0, s_eff, chunk):
        hi = min(lo + chunk, s_eff)
        total = total + chunk_loss(x[:, lo:hi], tokens[:, lo + 1:hi + 1])
    return total / (b * s_eff)


def stack_layer_params(per_layer: list[dict]) -> dict:
    """[{leaf: (..)}, ...] -> {leaf: (L, ..)} for lax.scan."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
