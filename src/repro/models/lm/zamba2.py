"""Zamba2 hybrid — Mamba2 backbone with a *shared* attention block applied
every N layers (zamba2-1.2b: 38 mamba layers, shared block every 6).

Mamba2 block (SSD form, single B/C group): in-proj → short causal depthwise
conv → selective state-space recurrence with per-head scalar decay
``exp(dt·A)`` over state (head_dim × ssm_state) → gated RMS-norm → out-proj.
The recurrence is a ``lax.scan`` over time (O(1) decode state — long_500k
eligible). The shared attention block takes ``concat(h, x_embed)`` projected
back to d_model (the Zamba trick), has ONE set of weights reused at every
application point, but its own KV cache per application.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from . import layers as L
from .config import LMConfig


class Zamba2:
    def __init__(self, cfg: LMConfig, shard: L.Shard = L.no_shard):
        self.cfg = cfg
        self.shard = shard
        self.decode_ctx: L.DecodeShardCtx | None = None
        self.d_in = cfg.ssm_expand * cfg.d_model
        self.hd = cfg.ssm_head_dim
        self.n_heads_m = self.d_in // self.hd
        self.conv_dim = self.d_in + 2 * cfg.ssm_state
        self.attn_dims = L.AttnDims(
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.d_model // cfg.n_heads, d_model=cfg.d_model,
            rope_theta=cfg.rope_theta)

    # chunk boundaries between shared-attention applications
    def chunks(self) -> list[tuple[int, int]]:
        cfg = self.cfg
        if not cfg.shared_attn_every:
            return [(0, cfg.n_layers)]
        out, a = [], 0
        while a < cfg.n_layers:
            b = min(a + cfg.shared_attn_every, cfg.n_layers)
            out.append((a, b))
            a = b
        return out

    def n_shared(self) -> int:
        cfg = self.cfg
        if not cfg.shared_attn_every:
            return 0
        return sum(1 for (a, b) in self.chunks()
                   if b - a == cfg.shared_attn_every)

    # -- init -----------------------------------------------------------------
    def init_mamba_layer(self, key) -> dict:
        cfg = self.cfg
        d, din, n, h = cfg.d_model, self.d_in, cfg.ssm_state, self.n_heads_m
        dtype = jnp.dtype(cfg.dtype)
        ks = jax.random.split(key, 3)
        proj_out = 2 * din + 2 * n + h          # z, x, B, C, dt
        return {
            "ln": jnp.ones((d,), dtype=dtype),
            "w_in": jax.random.normal(ks[0], (d, proj_out), dtype=dtype)
                    * float(1.0 / np.sqrt(d)),
            "conv_w": jax.random.normal(ks[1], (cfg.conv_kernel,
                                                self.conv_dim), dtype=dtype)
                      * float(1.0 / np.sqrt(cfg.conv_kernel)),
            "a_log": jnp.zeros((h,), dtype=jnp.float32),
            "dt_bias": jnp.zeros((h,), dtype=jnp.float32),
            "d_skip": jnp.ones((h,), dtype=dtype),
            "ln_y": jnp.ones((din,), dtype=dtype),
            "w_out": jax.random.normal(ks[2], (din, d), dtype=dtype)
                     * float(1.0 / np.sqrt(din)),
        }

    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        keys = jax.random.split(key, cfg.n_layers + 4)
        params = {
            "embed": jax.random.normal(
                keys[0], (cfg.vocab, cfg.d_model), dtype=dtype) * 0.02,
            "mamba": L.stack_layer_params(
                [self.init_mamba_layer(keys[1 + i])
                 for i in range(cfg.n_layers)]),
            "final_norm": jnp.ones((cfg.d_model,), dtype=dtype),
            "lm_head": jax.random.normal(
                keys[-1], (cfg.d_model, cfg.vocab), dtype=dtype) * 0.02,
        }
        if self.n_shared():
            k1, k2, k3 = jax.random.split(keys[-2], 3)
            params["shared"] = {
                "ln_in": jnp.ones((2 * cfg.d_model,), dtype=dtype),
                "w_in": jax.random.normal(
                    k1, (2 * cfg.d_model, cfg.d_model), dtype=dtype)
                    * float(1.0 / np.sqrt(2 * cfg.d_model)),
                "ln1": jnp.ones((cfg.d_model,), dtype=dtype),
                "ln2": jnp.ones((cfg.d_model,), dtype=dtype),
                "attn": L.init_attn(k2, self.attn_dims, dtype),
                "mlp": L.init_swiglu(k3, cfg.d_model, cfg.d_ff, dtype),
            }
        return params

    # -- mamba core -----------------------------------------------------------
    def _split_proj(self, z):
        din, n, h = self.d_in, self.cfg.ssm_state, self.n_heads_m
        zg = z[..., :din]
        xs = z[..., din:2 * din]
        bb = z[..., 2 * din:2 * din + n]
        cc = z[..., 2 * din + n:2 * din + 2 * n]
        dt = z[..., 2 * din + 2 * n:]
        return zg, xs, bb, cc, dt

    def _conv(self, conv_in, conv_w, conv_state):
        """Causal depthwise conv; returns (out, new_state (b, k-1, C))."""
        k = conv_w.shape[0]
        full = jnp.concatenate([conv_state, conv_in], axis=1)
        s = conv_in.shape[1]
        out = sum(full[:, j:j + s, :] * conv_w[j][None, None, :]
                  for j in range(k))
        return out, full[:, -(k - 1):, :]

    def _ssm_scan(self, xh, bb, cc, dt, a_log, d_skip, state):
        """xh (b,s,h,hd); bb/cc (b,s,n); dt (b,s,h); state (b,h,hd,n)."""
        a = -jnp.exp(a_log)                                  # (h,)

        def step(S, inp):
            x_t, b_t, c_t, dt_t = inp                        # (b,h,hd),(b,n),(b,n),(b,h)
            decay = jnp.exp(dt_t * a[None, :])               # (b,h)
            contrib = (dt_t[..., None, None]
                       * x_t[..., :, None] * b_t[:, None, None, :])
            S = decay[..., None, None] * S + contrib
            y = jnp.einsum("bhpn,bn->bhp", S, c_t)
            return S, y

        xs = (jnp.moveaxis(xh, 1, 0), jnp.moveaxis(bb, 1, 0),
              jnp.moveaxis(cc, 1, 0), jnp.moveaxis(dt, 1, 0))
        state, ys = jax.lax.scan(step, state, xs)
        y = jnp.moveaxis(ys, 0, 1)                           # (b,s,h,hd)
        return y + d_skip[None, None, :, None] * xh, state

    def _mamba_block(self, layer, x, st):
        cfg = self.cfg
        b, s, d = x.shape
        h, hd = self.n_heads_m, self.hd
        xin = L.rms_norm(x, layer["ln"])
        z = xin @ layer["w_in"]
        zg, xs_, bb, cc, dt = self._split_proj(z)
        conv_in = jnp.concatenate([xs_, bb, cc], axis=-1)
        conv_out, conv_state = self._conv(conv_in, layer["conv_w"],
                                          st["conv"])
        conv_out = jax.nn.silu(conv_out)
        xs_, bb, cc = (conv_out[..., :self.d_in],
                       conv_out[..., self.d_in:self.d_in + cfg.ssm_state],
                       conv_out[..., self.d_in + cfg.ssm_state:])
        dt = jax.nn.softplus(dt.astype(jnp.float32)
                             + layer["dt_bias"][None, None, :])
        xh = xs_.reshape(b, s, h, hd)
        y, ssm_state = self._ssm_scan(xh, bb.astype(jnp.float32),
                                      cc.astype(jnp.float32), dt,
                                      layer["a_log"], layer["d_skip"],
                                      st["ssm"])
        y = y.reshape(b, s, self.d_in).astype(x.dtype)
        y = L.rms_norm(y, layer["ln_y"]) * jax.nn.silu(zg)
        out = y @ layer["w_out"]
        out = self.shard(out, ("batch", "seq", "embed"))
        return x + out, {"conv": conv_state, "ssm": ssm_state}

    def _zero_mamba_state(self, b):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        return {
            "conv": jnp.zeros((b, cfg.conv_kernel - 1, self.conv_dim),
                              dtype=dtype),
            "ssm": jnp.zeros((b, self.n_heads_m, self.hd, cfg.ssm_state),
                             dtype=jnp.float32),
        }

    # -- shared attention block -------------------------------------------------
    def _shared_block(self, p, x, x0, kv=None, idx=None):
        """Full-seq when kv is None; cached decode otherwise."""
        h = jnp.concatenate([x, x0], axis=-1)
        h = L.rms_norm(h, p["ln_in"]) @ p["w_in"]
        a_in = L.rms_norm(h, p["ln1"])
        if kv is None:
            attn = L.attention(p["attn"], self.attn_dims, a_in,
                               shard=self.shard, causal=True)
            new_kv = None
        else:
            k_cache, v_cache = kv
            attn, k_cache, v_cache = L.attention_decode(
                p["attn"], self.attn_dims, a_in, k_cache, v_cache, idx,
                shard=self.shard, decode_ctx=self.decode_ctx)
            new_kv = (k_cache, v_cache)
        h = h + attn
        h = h + L.swiglu(p["mlp"], L.rms_norm(h, p["ln2"]), self.shard)
        return x + h, new_kv

    # -- forward ----------------------------------------------------------------
    def _run(self, params, x, states, shared_kv=None, idx=None):
        """states: stacked (L, ...) mamba states; shared_kv: (n_shared k/v
        caches) or None for full-seq attention."""
        cfg = self.cfg
        x0 = x
        si = 0
        new_states = []
        new_kv = []
        for (a, b) in self.chunks():
            sub = jax.tree.map(lambda p: p[a:b], params["mamba"])
            st = jax.tree.map(lambda p: p[a:b], states)

            def step(carry, xs):
                layer, s_l = xs
                out, s_l = self._mamba_block(layer, carry, s_l)
                return out, s_l

            step_fn = jax.checkpoint(step) if cfg.remat else step
            x, st = jax.lax.scan(step_fn, x, (sub, st))
            new_states.append(st)
            if (b - a) == cfg.shared_attn_every and self.n_shared():
                if shared_kv is None:
                    x, _ = self._shared_block(params["shared"], x, x0)
                else:
                    kv = (shared_kv["k"][si], shared_kv["v"][si])
                    x, kv = self._shared_block(params["shared"], x, x0,
                                               kv=kv, idx=idx)
                    new_kv.append(kv)
                si += 1
        states = jax.tree.map(lambda *xs: jnp.concatenate(xs), *new_states)
        if new_kv:
            shared_kv = {
                "k": jnp.stack([kv[0] for kv in new_kv]),
                "v": jnp.stack([kv[1] for kv in new_kv]),
            }
        return x, states, shared_kv

    def forward(self, params, tokens, positions=None):
        b, s = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0)
        x = self.shard(x, ("batch", "seq", "embed"))
        states = jax.tree.map(
            lambda z: jnp.broadcast_to(z, (self.cfg.n_layers,) + z.shape),
            self._zero_mamba_state(b))
        x, _, _ = self._run(params, x, states)
        x = L.rms_norm(x, params["final_norm"])
        logits = x @ params["lm_head"]
        return self.shard(logits, ("batch", "seq", "vocab"))

    def loss(self, params, batch):
        tokens = batch["tokens"]
        b = tokens.shape[0]
        x = jnp.take(params["embed"], tokens, axis=0)
        x = self.shard(x, ("batch", "seq", "embed"))
        states = jax.tree.map(
            lambda z: jnp.broadcast_to(z, (self.cfg.n_layers,) + z.shape),
            self._zero_mamba_state(b))
        x, _, _ = self._run(params, x, states)
        return L.chunked_ce_loss(x, params["final_norm"],
                                 params["lm_head"], tokens,
                                 shard=self.shard)

    # -- serving ----------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        cache = {
            "mamba": jax.tree.map(
                lambda z: jnp.broadcast_to(
                    z, (cfg.n_layers,) + z.shape).copy(),
                self._zero_mamba_state(batch)),
            "index": jnp.zeros((), dtype=jnp.int32),
        }
        ns = self.n_shared()
        if ns:
            dtype = jnp.dtype(cfg.dtype)
            kv_shape = (ns, batch, max_len, cfg.n_kv_heads,
                        self.attn_dims.head_dim)
            cache["shared"] = {"k": jnp.zeros(kv_shape, dtype=dtype),
                               "v": jnp.zeros(kv_shape, dtype=dtype)}
        return cache

    def prefill(self, params, tokens, cache):
        """Prefill via full-seq mamba + full attention, then write the
        shared-attention KV from a replay of the attention inputs.

        For simplicity the shared KV cache is filled by running decode-style
        attention over the prefix inside the full pass (positions [0, s))."""
        cfg = self.cfg
        b, s = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0)
        x0 = x
        states = cache["mamba"]
        s_max = cache["shared"]["k"].shape[2] if self.n_shared() else 0
        si = 0
        new_states, ks, vs = [], [], []
        for (a, bnd) in self.chunks():
            sub = jax.tree.map(lambda p: p[a:bnd], params["mamba"])
            st = jax.tree.map(lambda p: p[a:bnd], states)

            def step(carry, xs):
                layer, s_l = xs
                return self._mamba_block(layer, carry, s_l)

            x, st = jax.lax.scan(step, x, (sub, st))
            new_states.append(st)
            if (bnd - a) == cfg.shared_attn_every and self.n_shared():
                p = params["shared"]
                h = jnp.concatenate([x, x0], axis=-1)
                h = L.rms_norm(h, p["ln_in"]) @ p["w_in"]
                a_in = L.rms_norm(h, p["ln1"])
                positions = jnp.arange(s, dtype=jnp.int32)[None, :]
                q, k, v = L._qkv(p["attn"], self.attn_dims, a_in, positions,
                                 self.shard)
                attn = L._attend(q, k, v, causal=True)
                attn = attn.reshape(b, s, -1) @ p["attn"]["wo"]
                h = h + attn
                h = h + L.swiglu(p["mlp"], L.rms_norm(h, p["ln2"]),
                                 self.shard)
                x = x + h
                pad = [(0, 0), (0, s_max - s), (0, 0), (0, 0)]
                ks.append(jnp.pad(k, pad))
                vs.append(jnp.pad(v, pad))
                si += 1
        states = jax.tree.map(lambda *t: jnp.concatenate(t), *new_states)
        x = L.rms_norm(x, params["final_norm"])
        logits = (x[:, -1:, :] @ params["lm_head"])[:, 0]
        cache = {"mamba": states, "index": jnp.asarray(s, jnp.int32)}
        if ks:
            cache["shared"] = {"k": jnp.stack(ks), "v": jnp.stack(vs)}
        return logits, cache

    def decode_step(self, params, tokens, cache):
        b = tokens.shape[0]
        idx = cache["index"]
        x = jnp.take(params["embed"], tokens, axis=0)
        x, states, shared_kv = self._run(
            params, x, cache["mamba"],
            shared_kv=cache.get("shared"), idx=idx)
        x = L.rms_norm(x, params["final_norm"])
        logits = (x @ params["lm_head"])[:, 0]
        new_cache = {"mamba": states, "index": idx + 1}
        if shared_kv is not None:
            new_cache["shared"] = shared_kv
        return logits, new_cache
