"""Model zoos: paper CTR models (repro.models.ctr) + assigned LM
architectures (repro.models.lm)."""
