"""Launch surface: mesh construction, per-cell step builders, dry-run CLI,
train/serve drivers."""

from .mesh import make_production_mesh, make_test_mesh

__all__ = ["make_production_mesh", "make_test_mesh"]
