"""Serving driver: CTR runtime/engine or LM generation, CPU-runnable.

    PYTHONPATH=src python -m repro.launch.serve --mode ctr --model dcnv2
    PYTHONPATH=src python -m repro.launch.serve --mode ctr --policy bucketed
    PYTHONPATH=src python -m repro.launch.serve --models deepfm,dcnv2 --async
    PYTHONPATH=src python -m repro.launch.serve --mode lm --arch llama3-8b

    # online model updates: stream synthetic trainer deltas while serving
    PYTHONPATH=src python -m repro.launch.serve --store cached \\
        --delta-every 100 --delta-rows 256

    # multi-chip serving on a simulated 8-device CPU mesh:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python -m repro.launch.serve --mesh data=4,model=2 \\
        --store cached --refresh-every 4

The CTR path is the compile→plan→engine→runtime flow: a ``ServingRuntime``
hosting one ``InferenceEngine`` (plan cache + batching policy picked by
``--policy``) per ``--models`` entry. With ``--async`` each engine's
background worker drains its queue (futures-based intake — the
``TimeoutBatch`` SLO fires without caller polling); without it the driver
drains synchronously per wave. ``--mesh data=N[,model=M]`` serves every
model through sharded plans: batches over the data axis, embedding tables
vocab-parallel over the model axis, cache refreshes placed to the plans'
shardings (on CPU, set ``XLA_FLAGS=--xla_force_host_platform_device_count``
*before* launch to simulate the chips).
"""

import argparse

import numpy as np
import jax

from repro.configs import ARCH_NAMES, ctr_spec, get_config


def _make_policy(args):
    from repro.serving import BucketedBatch, FixedBatch, TimeoutBatch
    ladder = tuple(int(b) for b in args.buckets.split(","))
    if args.policy == "fixed":
        return FixedBatch(args.batch)
    if args.policy == "bucketed":
        return BucketedBatch(ladder)
    return TimeoutBatch(BucketedBatch(ladder), max_wait_ms=args.max_wait_ms)


def _make_mesh(spec: str | None):
    """``"data=4,model=2"`` -> a device mesh (None passes through).

    Axis order follows the spec string; sizes must multiply to at most
    ``jax.device_count()`` — on CPU, simulate chips with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set in the
    environment before python starts; jax reads it at first device use).
    """
    if not spec:
        return None
    from repro.compat import make_mesh
    axes, sizes = [], []
    for part in spec.split(","):
        name, _, size = part.partition("=")
        if not size:
            raise SystemExit(f"--mesh: expected axis=N, got {part!r}")
        axes.append(name.strip())
        sizes.append(int(size))
    need = int(np.prod(sizes))
    have = jax.device_count()
    if need > have:
        raise SystemExit(
            f"--mesh {spec} needs {need} devices, found {have}; on CPU "
            "launch with XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{need}")
    return make_mesh(tuple(sizes), tuple(axes))


def _traffic(args, schema):
    from repro.data.synthetic import zipf_ids
    if args.zipf:
        return np.asarray(zipf_ids(jax.random.PRNGKey(0), args.requests,
                                   schema.field_sizes, exponent=args.zipf))
    rng = np.random.default_rng(0)
    return np.stack([np.array([rng.integers(0, s)
                               for s in schema.field_sizes], dtype=np.int32)
                     for _ in range(args.requests)])


def _engine_line(name, eng, scores, store, use_async):
    s = eng.stats
    emb = (f"  emb_hit={s.emb_cache_hit_rate:.1%} "
           f"cached_traffic={s.emb_cached_traffic_fraction:.1%} "
           f"refreshes={s.emb_cache_refreshes}" if store else "")
    if store == "host":
        emb += (f" prefetch_hit={s.emb_prefetch_hit_rate:.1%} "
                f"staged={s.emb_staged_rows} h2d={s.emb_h2d_bytes}B")
    if s.emb_quant_rows:
        emb += (f" gather={s.emb_gather_bytes}B "
                f"quant_saved={s.emb_quant_bytes_saved}B")
    if s.mlp_quant_matmuls:
        emb += (f" q8_matmuls={s.mlp_quant_matmuls} "
                f"w_saved={s.mlp_quant_weight_bytes_saved}B")
    mode = "async" if use_async else "sync"
    print(f"[serve:{mode}] {name}: {s.n_requests} requests in "
          f"{s.n_batches} batches  p50={s.p50_ms:.1f}ms "
          f"p99={s.p99_ms:.1f}ms  plans={len(eng.cached_plans)} "
          f"cache_h/m={s.cache_hits}/{s.cache_misses} "
          f"pad_waste={s.padding_waste:.1%} "
          f"mean_score={scores.mean():.4f}{emb}")


def serve_ctr(args) -> None:
    from repro.data.synthetic import CRITEO
    from repro.models.ctr import CTR_MODELS
    from repro.serving import ServingRuntime
    names = [n.strip() for n in
             (args.models.split(",") if args.models else [args.model])]
    schema = CRITEO.scaled(100_000)
    mesh = _make_mesh(args.mesh)
    if mesh is not None:
        print(f"[serve] mesh {dict(mesh.shape)} over "
              f"{mesh.devices.size} devices")
    rt = ServingRuntime(refresh_every=args.runtime_refresh_every,
                        mesh=mesh, scheduler=args.sched,
                        pool_size=args.pool_size,
                        delta_every=args.delta_every)
    for name in names:
        spec = ctr_spec(name, "criteo", 16, 256, max_field=100_000)
        model = CTR_MODELS[name](spec)
        params = model.init(jax.random.PRNGKey(0))
        row_dtype = None if args.emb_dtype == "fp32" else args.emb_dtype
        store = None
        if args.store == "cached":
            from repro.embedding import CachedStore
            store = CachedStore(spec.embedding_spec(),
                                capacity=args.cache_capacity,
                                row_dtype=row_dtype)
        elif args.store == "host":
            from repro.embedding import HostBackedStore
            store = HostBackedStore(spec.embedding_spec(),
                                    capacity=args.cache_capacity,
                                    row_dtype=row_dtype)
        elif row_dtype is not None:
            raise SystemExit("--emb-dtype int8 needs a tiered store "
                             "(--store cached or host); DenseStore stays "
                             "full-precision")
        rt.add_model(name, model, params, level=args.level,
                     policy=_make_policy(args), store=store,
                     refresh_every=args.refresh_every,
                     compute_dtype=args.mlp_dtype)
    if args.delta_every:
        if args.store == "dense":
            raise SystemExit("--delta-every needs a refreshable store "
                             "(--store cached or host); DenseStore tensors "
                             "are compiled into plans as constants")
        from repro.serving import SyntheticTrainer
        # one synthetic trainer per model: enough batches that the stream
        # outlives the traffic, drained on the shared admission clock
        n_batches = max(1, args.requests // args.delta_every)
        for i, name in enumerate(names):
            trainer = SyntheticTrainer(rt.engine(name).store.spec,
                                       rows_per_batch=args.delta_rows,
                                       n_batches=n_batches, seed=i)
            rt.attach_delta_stream(name, trainer)
    rt.warmup()
    ids = _traffic(args, schema)

    if args.use_async:
        # futures-based intake: round-robin the stream over the hosted
        # models; --sched shared (default) drains every queue through one
        # DeviceScheduler pool, --sched per-engine gives each its worker
        rt.start()
        futs = {n: [] for n in names}
        for i, row in enumerate(ids):
            name = names[i % len(names)]
            futs[name].append(rt.submit(name, row))
        scores = {n: np.array([f.result(timeout=120.0) for f in fs])
                  for n, fs in futs.items()}
        rt.stop()
    else:
        scores = {}
        for j, name in enumerate(names):
            eng = rt.engine(name)
            # submit through the runtime so the shared admission cadence
            # (--runtime-refresh-every) sees the traffic
            rt.submit_many(name, list(ids[j::len(names)]))
            scores[name] = np.concatenate([eng.serve_pending(), eng.flush()])

    for name in names:
        _engine_line(name, rt.engine(name), scores[name],
                     args.store if args.store != "dense" else None,
                     args.use_async)
    if len(names) > 1:
        agg = rt.stats()
        print(f"[serve:runtime] {agg.n_models} models  "
              f"{agg.n_requests} requests in {agg.n_batches} batches  "
              f"p50={agg.p50_ms:.1f}ms p99={agg.p99_ms:.1f}ms  "
              f"refreshes={agg.emb_cache_refreshes}")
    if args.delta_every:
        # join any in-flight background pull (stop() is idempotent — the
        # async path already called it), then drain what the cadence
        # didn't reach so the summary is deterministic, not a race
        # against the pull thread
        rt.stop()
        rt.pull_updates()
        agg = rt.stats()
        print(f"[serve:delta] pushes={agg.emb_delta_pushes} "
              f"rows={agg.emb_delta_rows} version=v{agg.emb_version} "
              f"behind={agg.rows_behind}rows/"
              f"{agg.seconds_behind * 1e3:.1f}ms")
    sched = rt.scheduler
    if args.use_async and sched is not None:
        shares = " ".join(f"{n}={s:.1%}" for n, s in sorted(
            sched.shares.items()))
        slack = rt.stats().sched_preempted_slack_ms
        print(f"[serve:sched] pool={sched.pool_size} "
              f"dispatches={sched.n_dispatches} "
              f"preempted_slack={slack:.1f}ms  device_time {shares}")


def serve_lm(args) -> None:
    from repro.models.lm import make_lm_model
    from repro.serving import generate
    cfg = get_config(args.arch).reduced()
    model = make_lm_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, 8), 0, cfg.vocab)
    out = generate(model, params, prompt, max_new=args.max_new)
    print(f"[serve] {args.arch} (reduced): generated "
          f"{out.shape} tokens; head: {out[0, 8:14].tolist()}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["ctr", "lm"], default="ctr")
    ap.add_argument("--model", default="dcnv2")
    ap.add_argument("--models", default=None,
                    help="comma-separated model list for the multi-model "
                         "runtime (overrides --model), e.g. deepfm,dcnv2")
    ap.add_argument("--async", dest="use_async", action="store_true",
                    help="futures-based intake drained by background "
                         "workers instead of caller-driven serve_pending")
    ap.add_argument("--sched", default="shared",
                    choices=["shared", "per-engine"],
                    help="async drain mode: 'shared' (default) runs one "
                         "DeviceScheduler pool over every hosted engine "
                         "(constant thread count, least-SLO-slack-first); "
                         "'per-engine' keeps one worker thread per engine")
    ap.add_argument("--pool-size", type=int, default=2,
                    help="worker threads in the shared scheduler pool")
    ap.add_argument("--arch", default="llama3-8b", choices=list(ARCH_NAMES))
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--level", default="dual",
                    choices=["naive", "fused_emb", "fused_all", "dual"])
    ap.add_argument("--policy", default="bucketed",
                    choices=["fixed", "bucketed", "timeout"])
    ap.add_argument("--buckets", default="16,32,64,128,256",
                    help="comma-separated bucket ladder for bucketed/timeout")
    ap.add_argument("--max-wait-ms", type=float, default=5.0)
    ap.add_argument("--requests", type=int, default=500)
    ap.add_argument("--mesh", default=None,
                    help="device mesh for multi-chip serving, e.g. "
                         "'data=8' or 'data=4,model=2' (batches shard "
                         "over data, embedding tables over model)")
    ap.add_argument("--store", default="dense",
                    choices=["dense", "cached", "host"],
                    help="embedding store tier (repro.embedding); 'host' "
                         "keeps the backing table out of device memory")
    ap.add_argument("--cache-capacity", type=int, default=65536,
                    help="hot-row capacity C for --store cached/host")
    ap.add_argument("--emb-dtype", default="fp32",
                    choices=["fp32", "int8"],
                    help="wire dtype of cached/host store rows: int8 "
                         "stores rows quantized (absmax + per-row fp32 "
                         "scale), ~4x less gather/h2d traffic, dequant "
                         "in-kernel; fp32 (default) stays bit-exact")
    ap.add_argument("--mlp-dtype", default="fp32",
                    choices=["fp32", "int8"],
                    help="dense-branch compute dtype: int8 runs every MLP "
                         "matmul quantized (per-channel int8 weights baked "
                         "at plan compile, per-row int8 activations, fused "
                         "in-kernel dequant+bias+ReLU); fp32 (default) "
                         "stays bit-exact")
    ap.add_argument("--refresh-every", type=int, default=None,
                    help="per-engine: rebuild the hot cache every N served "
                         "batches (plan cache survives — tensor swap)")
    ap.add_argument("--runtime-refresh-every", type=int, default=None,
                    help="runtime-wide: refresh all stores every N "
                         "submitted requests across models")
    ap.add_argument("--delta-every", type=int, default=None,
                    help="online model updates: pull a synthetic trainer's "
                         "delta stream every N submitted requests across "
                         "models (versioned double-buffered publish — no "
                         "recompiles); needs --store cached or host")
    ap.add_argument("--delta-rows", type=int, default=256,
                    help="embedding rows per synthetic delta batch for "
                         "--delta-every")
    ap.add_argument("--zipf", type=float, default=None,
                    help="zipf exponent for request traffic (default: "
                         "uniform random ids)")
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    if args.mode == "ctr":
        serve_ctr(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
