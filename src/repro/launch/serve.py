"""Serving driver: CTR engine or LM generation, reduced-config CPU-runnable.

    PYTHONPATH=src python -m repro.launch.serve --mode ctr --model dcnv2
    PYTHONPATH=src python -m repro.launch.serve --mode ctr --policy bucketed
    PYTHONPATH=src python -m repro.launch.serve --mode lm --arch llama3-8b

The CTR path is the compile→plan→engine flow: an ``InferenceEngine`` owning
a plan cache and a batching policy picked by ``--policy``.
"""

import argparse

import numpy as np
import jax

from repro.configs import ARCH_NAMES, ctr_spec, get_config


def _make_policy(args):
    from repro.serving import BucketedBatch, FixedBatch, TimeoutBatch
    ladder = tuple(int(b) for b in args.buckets.split(","))
    if args.policy == "fixed":
        return FixedBatch(args.batch)
    if args.policy == "bucketed":
        return BucketedBatch(ladder)
    return TimeoutBatch(BucketedBatch(ladder), max_wait_ms=args.max_wait_ms)


def serve_ctr(args) -> None:
    from repro.data.synthetic import CRITEO, zipf_ids
    from repro.models.ctr import CTR_MODELS
    from repro.serving import InferenceEngine
    schema = CRITEO.scaled(100_000)
    spec = ctr_spec(args.model, "criteo", 16, 256, max_field=100_000)
    model = CTR_MODELS[args.model](spec)
    params = model.init(jax.random.PRNGKey(0))
    store = None
    if args.store == "cached":
        from repro.embedding import CachedStore
        store = CachedStore(spec.embedding_spec(),
                            capacity=args.cache_capacity)
    eng = InferenceEngine(model, params, level=args.level,
                          policy=_make_policy(args), store=store,
                          refresh_every=args.refresh_every)
    eng.warmup()
    if args.zipf:
        ids = np.asarray(zipf_ids(jax.random.PRNGKey(0), args.requests,
                                  schema.field_sizes, exponent=args.zipf))
        eng.submit_many(list(ids))
    else:
        rng = np.random.default_rng(0)
        for _ in range(args.requests):
            eng.submit(np.array([rng.integers(0, s)
                                 for s in schema.field_sizes],
                                dtype=np.int32))
    scores = np.concatenate([eng.serve_pending(), eng.flush()])
    s = eng.stats
    emb = (f"  emb_hit={s.emb_cache_hit_rate:.1%} "
           f"cached_traffic={s.emb_cached_traffic_fraction:.1%} "
           f"refreshes={s.emb_cache_refreshes}" if store else "")
    print(f"[serve] {args.model} level={args.level} policy={args.policy}: "
          f"{s.n_requests} requests in {s.n_batches} batches  "
          f"p50={s.p50_ms:.1f}ms p99={s.p99_ms:.1f}ms  "
          f"plans={len(eng.cached_plans)} cache_h/m="
          f"{s.cache_hits}/{s.cache_misses} pad_waste={s.padding_waste:.1%} "
          f"mean_score={scores.mean():.4f}{emb}")


def serve_lm(args) -> None:
    from repro.models.lm import make_lm_model
    from repro.serving import generate
    cfg = get_config(args.arch).reduced()
    model = make_lm_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, 8), 0, cfg.vocab)
    out = generate(model, params, prompt, max_new=args.max_new)
    print(f"[serve] {args.arch} (reduced): generated "
          f"{out.shape} tokens; head: {out[0, 8:14].tolist()}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["ctr", "lm"], default="ctr")
    ap.add_argument("--model", default="dcnv2")
    ap.add_argument("--arch", default="llama3-8b", choices=list(ARCH_NAMES))
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--level", default="dual",
                    choices=["naive", "fused_emb", "fused_all", "dual"])
    ap.add_argument("--policy", default="bucketed",
                    choices=["fixed", "bucketed", "timeout"])
    ap.add_argument("--buckets", default="16,32,64,128,256",
                    help="comma-separated bucket ladder for bucketed/timeout")
    ap.add_argument("--max-wait-ms", type=float, default=5.0)
    ap.add_argument("--requests", type=int, default=500)
    ap.add_argument("--store", default="dense", choices=["dense", "cached"],
                    help="embedding store tier (repro.embedding)")
    ap.add_argument("--cache-capacity", type=int, default=65536,
                    help="hot-row capacity C for --store cached")
    ap.add_argument("--refresh-every", type=int, default=None,
                    help="rebuild the hot cache every N served batches")
    ap.add_argument("--zipf", type=float, default=None,
                    help="zipf exponent for request traffic (default: "
                         "uniform random ids)")
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    if args.mode == "ctr":
        serve_ctr(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
