"""Serving driver: CTR engine or LM generation, reduced-config CPU-runnable.

    PYTHONPATH=src python -m repro.launch.serve --mode ctr --model dcnv2
    PYTHONPATH=src python -m repro.launch.serve --mode ctr --policy bucketed
    PYTHONPATH=src python -m repro.launch.serve --mode lm --arch llama3-8b

The CTR path is the compile→plan→engine flow: an ``InferenceEngine`` owning
a plan cache and a batching policy picked by ``--policy``.
"""

import argparse

import numpy as np
import jax

from repro.configs import ARCH_NAMES, ctr_spec, get_config


def _make_policy(args):
    from repro.serving import BucketedBatch, FixedBatch, TimeoutBatch
    ladder = tuple(int(b) for b in args.buckets.split(","))
    if args.policy == "fixed":
        return FixedBatch(args.batch)
    if args.policy == "bucketed":
        return BucketedBatch(ladder)
    return TimeoutBatch(BucketedBatch(ladder), max_wait_ms=args.max_wait_ms)


def serve_ctr(args) -> None:
    from repro.data.synthetic import CRITEO
    from repro.models.ctr import CTR_MODELS
    from repro.serving import InferenceEngine
    schema = CRITEO.scaled(100_000)
    spec = ctr_spec(args.model, "criteo", 16, 256, max_field=100_000)
    model = CTR_MODELS[args.model](spec)
    params = model.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(model, params, level=args.level,
                          policy=_make_policy(args))
    eng.warmup()
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        eng.submit(np.array([rng.integers(0, s)
                             for s in schema.field_sizes], dtype=np.int32))
    scores = np.concatenate([eng.serve_pending(), eng.flush()])
    s = eng.stats
    print(f"[serve] {args.model} level={args.level} policy={args.policy}: "
          f"{s.n_requests} requests in {s.n_batches} batches  "
          f"p50={s.p50_ms:.1f}ms p99={s.p99_ms:.1f}ms  "
          f"plans={len(eng.cached_plans)} cache_h/m="
          f"{s.cache_hits}/{s.cache_misses} pad_waste={s.padding_waste:.1%} "
          f"mean_score={scores.mean():.4f}")


def serve_lm(args) -> None:
    from repro.models.lm import make_lm_model
    from repro.serving import generate
    cfg = get_config(args.arch).reduced()
    model = make_lm_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, 8), 0, cfg.vocab)
    out = generate(model, params, prompt, max_new=args.max_new)
    print(f"[serve] {args.arch} (reduced): generated "
          f"{out.shape} tokens; head: {out[0, 8:14].tolist()}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["ctr", "lm"], default="ctr")
    ap.add_argument("--model", default="dcnv2")
    ap.add_argument("--arch", default="llama3-8b", choices=list(ARCH_NAMES))
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--level", default="dual",
                    choices=["naive", "fused_emb", "fused_all", "dual"])
    ap.add_argument("--policy", default="bucketed",
                    choices=["fixed", "bucketed", "timeout"])
    ap.add_argument("--buckets", default="16,32,64,128,256",
                    help="comma-separated bucket ladder for bucketed/timeout")
    ap.add_argument("--max-wait-ms", type=float, default=5.0)
    ap.add_argument("--requests", type=int, default=500)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    if args.mode == "ctr":
        serve_ctr(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
