import os
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"])

"""Training driver for the assigned LM architectures.

On real hardware this runs the sharded train step on the production mesh;
on this CPU container use ``--reduced`` for a runnable end-to-end loop or
``REPRO_DRYRUN_DEVICES=512`` for compile-only validation.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
        --steps 20
"""

import argparse
import dataclasses

import jax

import repro.configs as C
from repro.configs import ARCH_NAMES, get_config
from repro.data.synthetic import synthetic_batch
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.launch.steps import build_cell
from repro.models.lm import make_lm_model
from repro.training import (TrainLoopConfig, adamw_init, run_train_loop)
from repro.training.optimizer import AdamWConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_NAMES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = make_lm_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamWConfig(lr=1e-3)
    state = adamw_init(params, opt)

    import jax.numpy as jnp
    from repro.training.optimizer import adamw_update

    @jax.jit
    def step_fn(state, batch):
        loss, grads = jax.value_and_grad(model.loss)(state.params, batch)
        state, m = adamw_update(state, grads, opt)
        return state, {"loss": loss, **m}

    def batch_fn(step):
        key = jax.random.fold_in(jax.random.PRNGKey(0), step)
        batch = {"tokens": jax.random.randint(
            key, (args.batch, args.seq), 0, cfg.vocab)}
        if cfg.family == "encdec":
            batch["frames"] = jax.random.normal(
                key, (args.batch, args.seq, cfg.d_model),
                dtype=jnp.dtype(cfg.dtype)) * 0.1
        if cfg.family == "vlm":
            batch["patch_embeds"] = jax.random.normal(
                key, (args.batch, 4, cfg.d_model),
                dtype=jnp.dtype(cfg.dtype)) * 0.02
        return batch

    loop = TrainLoopConfig(total_steps=args.steps, ckpt_every=50,
                           ckpt_dir=args.ckpt_dir, resume=args.resume,
                           log_every=10)
    state, hist = run_train_loop(step_fn, state, batch_fn, loop)
    print(f"[train] {args.arch}: loss {hist[0]['loss']:.4f} -> "
          f"{hist[-1]['loss']:.4f} over {len(hist)} steps")


if __name__ == "__main__":
    main()
