"""Production mesh construction.

Single pod:  (data=16, model=16)             = 256 chips (v5e pod)
Multi-pod :  (pod=2, data=16, model=16)      = 512 chips

Defined as a FUNCTION so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS *before* any jax import; everything else
sees the real single-CPU device).
"""

from __future__ import annotations

import jax

from repro.compat import make_mesh

__all__ = ["make_production_mesh", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2,
                   pod: int | None = None) -> jax.sharding.Mesh:
    """Small mesh for CI-scale sharding tests (requires
    --xla_force_host_platform_device_count >= data*model*(pod or 1))."""
    if pod:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))
