import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh(es) and emit memory / cost / roofline records.

The XLA_FLAGS line above MUST stay the first statement — jax locks the
device count on first init.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out experiments/dryrun
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.analysis.roofline import analyze_cell
from repro.configs import ARCH_NAMES, SHAPES, applicable_shapes
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell


def run_cell(arch: str, shape: str, mesh_name: str, out_dir: str | None,
             roofline: bool = True) -> dict:
    multi_pod = mesh_name == "multipod"
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()
    cell = build_cell(arch, shape, mesh)
    lowered, kind = cell.lower()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "chips": chips,
        "kind": kind, "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "n_micro": cell.n_micro,
        "memory": {
            "arg_GiB": round(ma.argument_size_in_bytes / 2**30, 3),
            "out_GiB": round(ma.output_size_in_bytes / 2**30, 3),
            "temp_GiB": round(ma.temp_size_in_bytes / 2**30, 3),
        },
    }
    print(compiled.memory_analysis())
    from repro.compat import cost_analysis
    ca = cost_analysis(compiled)
    print({k: ca[k] for k in ("flops", "bytes accessed")
           if k in ca})
    if roofline:
        rep = analyze_cell(arch, shape, mesh_name, chips, compiled,
                           n_micro=cell.n_micro)
        rec["roofline"] = dataclasses.asdict(rep)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        safe = arch.replace(".", "_")
        with open(os.path.join(out_dir,
                               f"{safe}__{shape}__{mesh_name}.json"),
                  "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, choices=list(ARCH_NAMES),
                    help="one architecture (default: with --all, every one)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true",
                    help="run every applicable (arch × shape) cell")
    ap.add_argument("--out", default=None, help="JSON output directory")
    ap.add_argument("--no-roofline", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_NAMES)
    meshes = (["pod", "multipod"] if args.mesh == "both" else [args.mesh])
    if not args.all and args.arch is None:
        ap.error("pass --arch or --all")

    results = []
    for arch in archs:
        app = applicable_shapes(arch)
        shapes = [args.shape] if args.shape else list(SHAPES)
        for shape in shapes:
            for mesh_name in meshes:
                if app[shape] != "run":
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "skip", "reason": app[shape]}
                    print(f"[dryrun] SKIP  {arch:28s} {shape:12s} "
                          f"{mesh_name}: {app[shape][:60]}", flush=True)
                    results.append(rec)
                    continue
                print(f"[dryrun] CELL  {arch:28s} {shape:12s} {mesh_name}",
                      flush=True)
                try:
                    rec = run_cell(arch, shape, mesh_name, args.out,
                                   roofline=not args.no_roofline)
                    rl = rec.get("roofline", {})
                    print(f"[dryrun]   ok: compile={rec['compile_s']}s "
                          f"temp={rec['memory']['temp_GiB']}GiB "
                          f"dominant={rl.get('dominant', '?')}", flush=True)
                except Exception as e:   # noqa: BLE001 — record and continue
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "FAIL", "error": repr(e)}
                results.append(rec)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_fail = sum(r["status"] == "FAIL" for r in results)
    print(f"[dryrun] total={len(results)} ok={n_ok} skip={n_skip} "
          f"fail={n_fail}")
    if args.out:
        with open(os.path.join(args.out, "summary.json"), "w") as f:
            json.dump(results, f, indent=1, default=str)
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
