"""Uniform step functions per (arch × shape-kind), mesh-aware.

Adapters flatten per-family signature differences into
``step(state_or_params, inputs_dict)`` so the dry-run, trainer, and server
share one calling convention keyed by ``repro.configs.input_specs``.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config, input_specs
from repro.distributed import sharding as shd
from repro.models.lm import make_lm_model
from repro.models.lm import layers as LD
from repro.training.optimizer import (AdamWConfig, TrainState, adamw_init,
                                      adamw_update)

__all__ = ["build_cell", "Cell"]


class Cell:
    """Everything needed to lower one (arch × shape × mesh) cell."""

    def __init__(self, arch: str, shape: str, mesh, policy: str = "auto"):
        self.arch = arch
        self.shape = shape
        self.mesh = mesh
        self.cfg = get_config(arch)
        self.cell = SHAPES[shape]
        if policy == "auto":
            # H2: dense-family training is collective-bound under TP
            # (per-layer activation all-reduces); pure FSDP halves the
            # collective term and goes compute-bound — but its backward
            # keeps an unsharded stacked weight-grad buffer (EXPERIMENTS
            # §Perf H2), so it is the default only where the compiled
            # footprint was measured to fit v5e HBM.
            fsdp_ok = arch in ("granite-8b", "smollm-360m", "whisper-small")
            grid = mesh.shape.get("data", 1) * mesh.shape.get("model", 1)
            policy = ("fsdp" if fsdp_ok and self.cell.kind == "train"
                      and self.cell.batch % grid == 0 else "tp_fsdp")
        self.policy = policy
        self.shard = shd.make_shard_fn(mesh, policy)
        self.model = make_lm_model(self.cfg, self.shard)
        self.inputs_sds = input_specs(arch, shape)

        if self.cell.kind == "decode" and self.cfg.family != "ssm":
            # H1: distributed flash-decode over the seq-sharded KV cache
            # (without this, GSPMD all-gathers the cache per layer)
            baxes = shd.mesh_batch_axes(mesh)
            nb = 1
            for a in baxes:
                nb *= mesh.shape[a]
            b_ax = (baxes if len(baxes) > 1 else baxes[0]) \
                if self.cell.batch % max(nb, 1) == 0 else None
            self.model.decode_ctx = LD.DecodeShardCtx(
                mesh=mesh, batch_axes=b_ax, seq_axis="model")

        pshapes = jax.eval_shape(
            lambda: self.model.init(jax.random.PRNGKey(0)))
        self.param_shapes = pshapes
        pspecs = shd.param_specs(self.cfg.family, pshapes, self.cfg)
        if self.policy == "fsdp":
            pspecs = shd.fsdp_param_specs(pspecs)
        if self.cell.kind == "decode":
            # H1b: serving keeps weights TP-only — FSDP over data would
            # all-gather every weight every step — unless the TP shard
            # itself exceeds HBM (llama4's 772B experts), where streamed
            # weight gathering is the only option on one pod.
            import numpy as _np
            pbytes = sum(int(_np.prod(x.shape)) * x.dtype.itemsize
                         for x in jax.tree.leaves(pshapes))
            if pbytes / mesh.shape.get("model", 1) <= 8 * 2**30:
                pspecs = shd.drop_axis(pspecs, "data")
        self.pspecs = shd.fit_spec_tree(mesh, pspecs, pshapes)

        # optimizer-state compression for the very large archs
        big = sum(int(jnp.prod(jnp.asarray(x.shape)))
                  for x in jax.tree.leaves(pshapes)) > 30_000_000_000
        self.opt_cfg = AdamWConfig(
            state_dtype="bfloat16" if big else "float32")
        self.n_micro = self._choose_microbatches()

    def _choose_microbatches(self) -> int:
        """Gradient-accumulation depth so the remat-scan activation carry
        fits comfortably (§Perf M3): target ≤ ~4 GiB of (b·s·d·2B·L) per
        device. Restricted to power-of-2 divisors of the per-device batch."""
        if self.cell.kind != "train":
            return 1
        cfg, cell = self.cfg, self.cell
        data_shards = 1
        for a in self._batch_axes():
            data_shards *= self.mesh.shape[a]
        local_b = max(cell.batch // data_shards, 1)
        l_eff = cfg.n_layers + cfg.encoder_layers   # enc-dec counts both
        carry_bytes = (local_b * cell.seq * cfg.d_model * 2
                       * max(l_eff, 1))
        n = 1
        while (carry_bytes / n > 4 * 2**30 and n < local_b
               and local_b % (n * 2) == 0):
            n *= 2
        return n

    # -- shardings --------------------------------------------------------------
    def state_shapes(self):
        return jax.eval_shape(
            functools.partial(adamw_init, cfg=self.opt_cfg),
            self.param_shapes)

    def state_specs(self):
        return TrainState(step=P(), params=self.pspecs,
                          m=self.pspecs, v=self.pspecs)

    def _batch_axes(self) -> tuple[str, ...]:
        if self.policy == "fsdp":
            return tuple(a for a in ("data", "model")
                         if a in self.mesh.axis_names)
        return shd.mesh_batch_axes(self.mesh)

    def input_shardspecs(self):
        baxes = self._batch_axes()
        b = baxes if len(baxes) > 1 else baxes[0]
        specs = {}
        for k, v in self.inputs_sds.items():
            if k == "cache":
                specs[k] = shd.cache_specs(self.cfg.family, self.mesh, v)
            else:
                specs[k] = jax.tree.map(
                    lambda x: shd.P(*([b] + [None] * (x.ndim - 1))), v)
            specs[k] = shd.fit_spec_tree(self.mesh, specs[k], v)
        return specs

    # -- step functions -----------------------------------------------------------
    def train_step_fn(self) -> Callable:
        model, opt_cfg, n_micro = self.model, self.opt_cfg, self.n_micro
        gspecs = shd.to_named(self.mesh, self.pspecs)

        def train_step(state: TrainState, batch: dict):
            if n_micro == 1:
                loss, grads = jax.value_and_grad(model.loss)(
                    state.params, batch)
            else:
                # gradient accumulation over microbatches (scan keeps HLO
                # O(1) in n_micro; grads accumulate in f32, sharded like
                # their parameters)
                micro = jax.tree.map(
                    lambda x: x.reshape(n_micro, x.shape[0] // n_micro,
                                        *x.shape[1:]), batch)
                g0 = jax.tree.map(
                    lambda p, s: jax.lax.with_sharding_constraint(
                        jnp.zeros(p.shape, jnp.float32), s),
                    state.params, gspecs)

                def acc(carry, mb):
                    loss_sum, g = carry
                    l, gi = jax.value_and_grad(model.loss)(state.params, mb)
                    g = jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32), g, gi)
                    return (loss_sum + l, g), None

                (loss, grads), _ = jax.lax.scan(
                    acc, (jnp.zeros((), jnp.float32), g0), micro)
                loss = loss / n_micro
                grads = jax.tree.map(lambda g: g / n_micro, grads)
            state, metrics = adamw_update(state, grads, opt_cfg)
            return state, {"loss": loss, **metrics}
        return train_step

    def prefill_fn(self) -> Callable:
        model, cfg, cell = self.model, self.cfg, self.cell

        def prefill(params, inputs: dict):
            tokens = inputs["tokens"]
            b = tokens.shape[0]
            if cfg.family == "encdec":
                cache = model.init_cache(b, cell.seq,
                                         inputs["frames"].shape[1])
                return model.prefill(params, tokens, inputs["frames"], cache)
            if cfg.family == "vlm":
                s_total = tokens.shape[1] + inputs["patch_embeds"].shape[1]
                cache = model.init_cache(b, s_total)
                return model.prefill(params, tokens, cache,
                                     patch_embeds=inputs["patch_embeds"])
            if cfg.family == "ssm":
                cache = model.init_cache(b, 0)
                return model.prefill(params, tokens, cache)
            cache = model.init_cache(b, cell.seq)
            return model.prefill(params, tokens, cache)
        return prefill

    def decode_fn(self) -> Callable:
        model = self.model

        def serve_step(params, inputs: dict):
            return model.decode_step(params, inputs["tokens"],
                                     inputs["cache"])
        return serve_step

    # -- lowering -----------------------------------------------------------------
    def lower(self):
        """Returns (lowered, kind)."""
        mesh = self.mesh
        named = lambda t: shd.to_named(mesh, t)
        if self.cell.kind == "train":
            step = self.train_step_fn()
            st_sds = self.state_shapes()
            st_named = named(
                jax.tree.map(lambda s: s, self.state_specs(),
                             is_leaf=lambda s: isinstance(s, P)))
            in_named = named(self.input_shardspecs())
            with mesh:
                lowered = jax.jit(
                    step,
                    in_shardings=(st_named, in_named),
                    out_shardings=(st_named, None),
                    donate_argnums=(0,),
                ).lower(st_sds, self.inputs_sds)
            return lowered, "train"
        if self.cell.kind == "prefill":
            step = self.prefill_fn()
            in_named = named(self.input_shardspecs())
            with mesh:
                lowered = jax.jit(
                    step,
                    in_shardings=(named(self.pspecs), in_named),
                ).lower(self.param_shapes, self.inputs_sds)
            return lowered, "prefill"
        step = self.decode_fn()
        in_named = named(self.input_shardspecs())
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(named(self.pspecs), in_named),
                donate_argnums=(1,),
            ).lower(self.param_shapes, self.inputs_sds)
        return lowered, "decode"


def build_cell(arch: str, shape: str, mesh) -> Cell:
    return Cell(arch, shape, mesh)
