"""repro — DPIFrame (dual-level-parallelism CTR inference) on TPU in JAX+Pallas.

Public API surface:
    repro.core            the paper's contribution (fused embedding, opgraph,
                          breadth-first scheduler, dual-parallel executor)
    repro.kernels         Pallas TPU kernels + jnp reference oracles
    repro.models          CTR model zoo (paper) + LM architecture zoo (assigned)
    repro.configs         architecture registry (``get_config(name)``)
    repro.launch          mesh construction, dry-run, train/serve drivers
    repro.analysis        roofline accounting from compiled HLO
"""

__version__ = "0.1.0"
