"""Pure-jnp / numpy reference oracles for every Pallas kernel in this package.

Each ``ref_*`` function is the ground truth the kernels are tested against
(``tests/test_kernels.py`` sweeps shapes and dtypes with assert_allclose).

``multi_table_lookup_alg1`` is a *literal* transcription of the paper's
Algorithm 1 (flat element-wise traversal of the output matrix) — O(b·k·d)
scalar Python, used only at tiny sizes to anchor the vectorized oracles.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Algorithm 1 — multi-table lookup
# ---------------------------------------------------------------------------

def multi_table_lookup_alg1(ids: np.ndarray, tables: list[np.ndarray]) -> np.ndarray:
    """Literal transcription of DPIFrame Algorithm 1 (element-by-element).

    Args:
        ids:    (b, k) integer feature IDs; ``ids[s, i]`` indexes table ``i``.
        tables: list of k arrays, the i-th of shape (n_i, d).

    Returns:
        (b, k*d) lookup results, exactly the paper's ``EmbedOut``.
    """
    b, k = ids.shape
    d = tables[0].shape[1]
    ids_flat = ids.reshape(-1)                       # paper indexes IDs[row*k + table_id]
    total_elements = b * k * d                       # line 1
    row_width = k * d                                # line 2
    out = np.empty(total_elements, dtype=tables[0].dtype)
    for idx in range(total_elements):                # line 3
        row = idx // row_width                       # line 4
        col = idx % row_width                        # line 5
        table_id = col // d                          # line 6
        emb_row = ids_flat[row * k + table_id]       # line 7
        emb_col = col % d                            # line 8
        table = tables[table_id].reshape(-1)
        out[idx] = table[emb_row * d + emb_col]      # line 9
    return out.reshape(b, row_width)


def ref_multi_table_lookup(ids, mega_table, offsets, k: int):
    """Vectorized oracle over the concatenated mega-table.

    Args:
        ids:        (b, k) per-field IDs (local to each table).
        mega_table: (sum_i n_i, d) all k tables concatenated along rows.
        offsets:    (k,) row offset of each table inside ``mega_table``.
        k:          number of feature fields.

    Returns:
        (b, k*d) embedding output.
    """
    b = ids.shape[0]
    d = mega_table.shape[1]
    flat_rows = (ids + offsets[None, :]).reshape(-1)          # (b*k,) global rows
    gathered = jnp.take(mega_table, flat_rows, axis=0)        # (b*k, d)
    return gathered.reshape(b, k * d)


def ref_serial_lookup(ids, tables):
    """The *baseline* the paper accelerates: k independent lookups + concat.

    Mirrors a per-field ``nn.Embedding`` loop (PyTorch-A analogue): every
    field materializes its own (b, d) intermediate before concatenation.
    """
    cols = [jnp.take(tables[i], ids[:, i], axis=0) for i in range(len(tables))]
    return jnp.concatenate(cols, axis=1)


def ref_multi_hot_lookup(ids, weights, mega_table, offsets):
    """Multi-hot (sequence-feature) oracle: weighted sum over the hot axis.

    Args:
        ids:        (b, k, h) per-field IDs, h = max hot count.
        weights:    (b, k, h) 0/1 validity mask (or arbitrary pooling weights).
        mega_table: (N, d).
        offsets:    (k,).

    Returns:
        (b, k*d) pooled embedding output.
    """
    b, k, h = ids.shape
    d = mega_table.shape[1]
    rows = (ids + offsets[None, :, None]).reshape(-1)
    gathered = jnp.take(mega_table, rows, axis=0).reshape(b, k, h, d)
    pooled = jnp.sum(gathered * weights[..., None].astype(mega_table.dtype), axis=2)
    return pooled.reshape(b, k * d)


def ref_two_level_gather(flat_rows, slot_of_row, cache, backing):
    """Two-level (cache + backing) gather oracle — the CachedStore lookup.

    Hits read their row from ``cache``, misses fall through to ``backing``;
    the not-taken tier is pinned to its row 0 (same address indirection the
    Pallas kernel performs in its index maps). Because cache rows are
    verbatim copies of backing rows, the result is *bitwise* equal to
    ``jnp.take(backing, flat_rows)``.

    Args:
        flat_rows:   (R,) int32 global rows.
        slot_of_row: (N,) int32 cache slot per global row, -1 = uncached.
        cache:       (C, d) hot-row copies.
        backing:     (N, d) full mega-table.

    Returns:
        (R, d) gathered rows.
    """
    slots = jnp.take(slot_of_row, flat_rows, axis=0)
    hit = slots >= 0
    from_cache = jnp.take(cache, jnp.maximum(slots, 0), axis=0)
    from_backing = jnp.take(backing, jnp.where(hit, 0, flat_rows), axis=0)
    return jnp.where(hit[:, None], from_cache, from_backing)


def ref_three_level_gather(flat_rows, slot_of_row, staging_slot_of_row,
                           cache, staging):
    """Three-level (cache / staging / zero-guard) gather oracle — the
    HostBackedStore lookup.

    Unlike the two-level gather there is *no device-resident backing* to
    fall through to: rows absent from both the cache and the per-batch
    staging buffer gather **zero** (the guard). Correctness is the serve
    path's contract — it stages every miss before the lookup — so on a
    correctly staged batch the result is bitwise equal to gathering from
    the host backing (cache and staging rows are verbatim copies).

    Args:
        flat_rows:           (R,) int32 global rows.
        slot_of_row:         (N,) int32 cache slot per row, -1 = uncached.
        staging_slot_of_row: (N,) int32 staging slot per row, -1 = unstaged.
        cache:               (C, d) hot-row copies.
        staging:             (S, d) this batch's staged miss rows.

    Returns:
        (R, d) gathered rows (zero where neither tier resolves).
    """
    cslots = jnp.take(slot_of_row, flat_rows, axis=0)
    sslots = jnp.take(staging_slot_of_row, flat_rows, axis=0)
    cache_hit = cslots >= 0
    stage_hit = jnp.logical_and(~cache_hit, sslots >= 0)
    from_cache = jnp.take(cache, jnp.maximum(cslots, 0), axis=0)
    from_staging = jnp.take(staging, jnp.maximum(sslots, 0), axis=0)
    out = jnp.where(cache_hit[:, None], from_cache,
                    jnp.where(stage_hit[:, None], from_staging, 0))
    return out.astype(cache.dtype)


def ref_two_level_gather_q8(flat_rows, slot_of_row, cache, cache_scale,
                            backing, backing_scale):
    """Quantized two-level gather oracle — the int8 CachedStore lookup.

    Mirrors ``mtl_gather_two_level_q8``'s arithmetic *exactly* (select the
    int8 payload, select the fp32 scale via the same hit predicate, one
    dequant multiply), so the kernel-vs-ref comparison is bitwise.

    Args:
        flat_rows:     (R,) int32 global rows.
        slot_of_row:   (N,) int32 cache slot per global row, -1 = uncached.
        cache:         (C, d) int8 hot-row copies.
        cache_scale:   (C, 1) fp32 per-row scales.
        backing:       (N, d) int8 full mega-table.
        backing_scale: (N, 1) fp32 per-row scales.

    Returns:
        (R, d) float32 dequantized rows.
    """
    slots = jnp.take(slot_of_row, flat_rows, axis=0)
    hit = slots >= 0
    safe_slots = jnp.maximum(slots, 0)
    miss_rows = jnp.where(hit, 0, flat_rows)
    q = jnp.where(hit[:, None],
                  jnp.take(cache, safe_slots, axis=0),
                  jnp.take(backing, miss_rows, axis=0)).astype(jnp.float32)
    s = jnp.where(hit[:, None],
                  jnp.take(cache_scale, safe_slots, axis=0),
                  jnp.take(backing_scale, miss_rows, axis=0))
    return q * s


def ref_three_level_gather_q8(flat_rows, slot_of_row, staging_slot_of_row,
                              cache, cache_scale, staging, staging_scale):
    """Quantized three-level gather oracle — the int8 HostBackedStore
    lookup (zero-guard included: rows in neither tier select an int8
    payload of 0, which dequantizes to exactly 0.0 under any scale).

    Args:
        flat_rows:           (R,) int32 global rows.
        slot_of_row:         (N,) int32 cache slot per row, -1 = uncached.
        staging_slot_of_row: (N,) int32 staging slot per row, -1 = unstaged.
        cache:               (C, d) int8 hot-row copies.
        cache_scale:         (C, 1) fp32 per-row scales.
        staging:             (S, d) int8 staged miss rows.
        staging_scale:       (S, 1) fp32 per-row scales.

    Returns:
        (R, d) float32 dequantized rows (zero where neither tier resolves).
    """
    cslots = jnp.take(slot_of_row, flat_rows, axis=0)
    sslots = jnp.take(staging_slot_of_row, flat_rows, axis=0)
    cache_hit = cslots >= 0
    stage_hit = sslots >= 0
    from_cache = jnp.take(cache, jnp.maximum(cslots, 0), axis=0)
    from_staging = jnp.take(staging, jnp.maximum(sslots, 0), axis=0)
    q = jnp.where(cache_hit[:, None], from_cache,
                  jnp.where(stage_hit[:, None], from_staging, 0)
                  ).astype(jnp.float32)
    s = jnp.where(cache_hit[:, None],
                  jnp.take(cache_scale, jnp.maximum(cslots, 0), axis=0),
                  jnp.take(staging_scale, jnp.maximum(sslots, 0), axis=0))
    return q * s


def ref_dense_matmul_q8(hq, hscale, wq, wscale, bias, relu: bool = True):
    """Quantized dense-layer oracle — the int8 MLP matmul.

    Mirrors ``dense_matmul_q8``'s arithmetic *exactly* (int8×int8→int32
    dot, widen to fp32, row scale then channel scale then bias, optional
    ReLU), so the kernel-vs-ref comparison in interpret mode is bitwise.

    Args:
        hq:     (b, fan_in) int8 per-row quantized activations.
        hscale: (b, 1) fp32 per-row activation scales.
        wq:     (fan_in, fan_out) int8 per-channel quantized weights.
        wscale: (1, fan_out) fp32 per-channel weight scales.
        bias:   (1, fan_out) fp32.
        relu:   apply the fused ReLU epilogue.

    Returns:
        (b, fan_out) float32 layer output.
    """
    acc = jax.lax.dot_general(hq, wq, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    out = acc.astype(jnp.float32) * hscale * wscale + bias
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


# ---------------------------------------------------------------------------
# Fused non-GEMM oracles (C5)
# ---------------------------------------------------------------------------

def ref_cross_v2_elementwise(x0, xw_plus, x):
    """DCNv2 cross-layer tail:  out = x0 * xw_plus + x.

    ``xw_plus = x_l @ W + b`` is produced by the (un-fused) GEMM; the fused
    kernel covers the remaining elementwise chain.
    """
    return x0 * xw_plus + x


def ref_cross_v1_elementwise(x0, xlw, bias, x):
    """DCNv1 cross-layer tail:  out = x0 * xlw + bias + x.

    ``xlw`` is the (b, 1) scalar-per-sample result of ``x_l · w``.
    """
    return x0 * xlw + bias[None, :] + x


def ref_fm_second_order(v):
    """Factorization-machine 2nd-order term.

    Args:
        v: (b, k, d) field embeddings.

    Returns:
        (b,) 0.5 * sum_d [ (sum_k v)^2 - sum_k v^2 ].
    """
    s = jnp.sum(v, axis=1)               # (b, d)
    sq = jnp.sum(v * v, axis=1)          # (b, d)
    return 0.5 * jnp.sum(s * s - sq, axis=-1)


def ref_mlp_tail(h, residual=None, act: str = "relu"):
    """Post-GEMM MLP tail: activation (+ optional residual)."""
    if act == "relu":
        h = jnp.maximum(h, 0)
    elif act == "gelu":
        h = 0.5 * h * (1 + jnp.tanh(0.7978845608028654 * (h + 0.044715 * h**3)))
    elif act == "silu":
        h = h * (1 / (1 + jnp.exp(-h)))
    elif act != "none":
        raise ValueError(f"unknown activation {act!r}")
    if residual is not None:
        h = h + residual
    return h
