"""Public jit'd wrappers for the DPIFrame kernels, with strategy dispatch.

The wrappers hide the backend question: on a TPU the Pallas kernels run
compiled; on this CPU container the same math runs either through
``interpret=True`` (kernel-body validation) or through the vectorized jnp
fused path (identical algorithm at the XLA level — one gather over the
mega-table — which is what the CPU benchmarks time).

Strategies for ``multi_table_lookup``:

  "auto"        pallas on TPU, jnp-fused elsewhere
  "pallas"      output-first Pallas gather (Alg. 1)          [C2+C3]
  "onehot"      one-hot MXU matmul (small fields)            [TPU-native]
  "jnp"         vectorized single-gather over the mega-table [C2 at XLA level]
  "serial"      per-field loop + concat (the paper's PyTorch baseline)
  "input_first" Fig.-11 strawman (field-major writes + transpose)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import quant

from . import ref
from .dense_matmul import dmm_q8
from .fused_cross import fused_cross_v1, fused_cross_v2
from .fused_fm import fused_fm_second_order
from .multi_table_lookup import (
    mtl_gather,
    mtl_gather_multihot,
    mtl_gather_three_level,
    mtl_gather_three_level_q8,
    mtl_gather_two_level,
    mtl_gather_two_level_q8,
    mtl_input_first,
    mtl_onehot,
)

__all__ = [
    "multi_table_lookup",
    "multi_table_lookup_multihot",
    "multi_table_lookup_cached",
    "multi_table_lookup_cached_multihot",
    "multi_table_lookup_cached_q8",
    "multi_table_lookup_cached_q8_multihot",
    "multi_table_lookup_host",
    "multi_table_lookup_host_multihot",
    "multi_table_lookup_host_q8",
    "multi_table_lookup_host_q8_multihot",
    "dense_matmul_q8",
    "fused_cross_v1",
    "fused_cross_v2",
    "fused_fm_second_order",
    "on_tpu",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _flat_rows(ids: jax.Array, offsets: jax.Array) -> jax.Array:
    """Alg. 1 lines 6–7 vectorized: local id -> global mega-table row."""
    return (ids.astype(jnp.int32) + offsets[None, :].astype(jnp.int32)).reshape(-1)


def multi_table_lookup(ids: jax.Array, mega_table: jax.Array,
                       offsets: jax.Array, *, strategy: str = "auto",
                       interpret: bool | None = None) -> jax.Array:
    """Fused multi-table embedding lookup (paper Algorithm 1).

    Args:
        ids:        (b, k) int32 per-field local ids.
        mega_table: (N, d) concatenated tables.
        offsets:    (k,) int32 starting row of each table.
        strategy:   see module docstring.
        interpret:  force Pallas interpret mode (defaults to not-on-TPU).

    Returns:
        (b, k*d) embedding output.
    """
    b, k = ids.shape
    d = mega_table.shape[1]
    if interpret is None:
        interpret = not on_tpu()
    if strategy == "auto":
        strategy = "pallas" if on_tpu() else "jnp"

    if strategy == "jnp":
        return ref.ref_multi_table_lookup(ids, mega_table, offsets, k)
    if strategy == "pallas":
        rows = _flat_rows(ids, offsets)
        return mtl_gather(rows, mega_table, interpret=interpret).reshape(b, k * d)
    if strategy == "input_first":
        rows = _flat_rows(ids, offsets)
        return mtl_input_first(rows, mega_table, k=k, interpret=interpret)
    if strategy == "serial":
        # reconstruct per-field tables views (baseline semantics; the extra
        # slicing is free under jit — the k separate gathers are the cost)
        sizes = jnp.diff(jnp.concatenate([offsets, jnp.array([mega_table.shape[0]])]))
        del sizes  # views below keep it simple: slice lazily per field
        cols = []
        for i in range(k):
            cols.append(jnp.take(mega_table, ids[:, i] + offsets[i], axis=0))
        return jnp.concatenate(cols, axis=1)
    raise ValueError(f"unknown strategy {strategy!r}")


def multi_table_lookup_cached(ids: jax.Array, cache: jax.Array,
                              backing: jax.Array, slot_of_row: jax.Array,
                              offsets: jax.Array, *, strategy: str = "auto",
                              interpret: bool | None = None) -> jax.Array:
    """Fused lookup through a tiered (cache + backing) embedding store.

    The CachedStore analogue of :func:`multi_table_lookup`: one two-level
    gather resolves every (field, id) — cached rows from ``cache``, misses
    from ``backing`` — bit-exact with the dense path because cache rows are
    verbatim copies.

    Args:
        ids:         (b, k) int32 per-field local ids.
        cache:       (C, d) hot-row copies.
        backing:     (N, d) full mega-table.
        slot_of_row: (N,) int32 cache slot per global row, -1 = uncached.
        offsets:     (k,) int32 starting row of each table.

    Returns:
        (b, k*d) embedding output.
    """
    b, k = ids.shape
    d = backing.shape[1]
    if interpret is None:
        interpret = not on_tpu()
    if strategy == "auto":
        strategy = "pallas" if on_tpu() else "jnp"
    rows = _flat_rows(ids, offsets)
    if strategy == "jnp":
        out = ref.ref_two_level_gather(rows, slot_of_row, cache, backing)
    elif strategy == "pallas":
        slots = jnp.take(slot_of_row, rows, axis=0)
        out = mtl_gather_two_level(rows, slots, cache, backing,
                                   interpret=interpret)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    return out.reshape(b, k * d)


def multi_table_lookup_cached_multihot(ids: jax.Array, mask: jax.Array,
                                       cache: jax.Array, backing: jax.Array,
                                       slot_of_row: jax.Array,
                                       offsets: jax.Array, *,
                                       strategy: str = "auto",
                                       interpret: bool | None = None
                                       ) -> jax.Array:
    """Multi-hot (pooled) fused lookup through a tiered store.

    Mirrors :func:`multi_table_lookup_multihot` exactly — the jnp path
    repeats the dense oracle's mask-multiply-sum with the gather swapped
    for the two-level one, the pallas path redirects masked slots to the
    backing zero row — so either store produces bitwise-identical pooling.

    Args:
        ids:         (b, k, h) local ids; invalid slots arbitrary.
        mask:        (b, k, h) 1 for valid slots, 0 otherwise.
        cache:       (C, d) hot-row copies.
        backing:     (N, d) full mega-table **with a trailing all-zero row**.
        slot_of_row: (N,) int32 index map.
        offsets:     (k,) table starts.

    Returns:
        (b, k*d) pooled output.
    """
    b, k, h = ids.shape
    d = backing.shape[1]
    if interpret is None:
        interpret = not on_tpu()
    if strategy == "auto":
        strategy = "pallas" if on_tpu() else "jnp"
    if strategy == "jnp":
        rows = (ids.astype(jnp.int32)
                + offsets[None, :, None].astype(jnp.int32)).reshape(-1)
        vals = ref.ref_two_level_gather(rows, slot_of_row, cache, backing)
        pooled = jnp.sum(vals.reshape(b, k, h, d)
                         * mask[..., None].astype(backing.dtype), axis=2)
        return pooled.reshape(b, k * d)
    if strategy == "pallas":
        zero_row = backing.shape[0] - 1
        rows = ids.astype(jnp.int32) + offsets[None, :, None].astype(jnp.int32)
        rows = jnp.where(mask.astype(bool), rows, zero_row).reshape(-1)
        slots = jnp.take(slot_of_row, rows, axis=0)
        out = mtl_gather_two_level(rows, slots, cache, backing, hot=h,
                                   interpret=interpret)
        return out.reshape(b, k * d)
    raise ValueError(f"unknown strategy {strategy!r}")


def multi_table_lookup_cached_q8(ids: jax.Array, cache: jax.Array,
                                 cache_scale: jax.Array, backing: jax.Array,
                                 backing_scale: jax.Array,
                                 slot_of_row: jax.Array, offsets: jax.Array,
                                 *, strategy: str = "auto",
                                 interpret: bool | None = None) -> jax.Array:
    """Quantized tiered lookup: int8 cache/backing rows, per-row fp32
    scales, dequantization inside the gather.

    The int8 twin of :func:`multi_table_lookup_cached` — same tier
    selection, ~``(d + 4) / 4d`` of its gather bytes, float32 output.
    Not bit-exact with the dense path (round-trip error ≤ scale/2 per
    element); the accuracy-parity benchmark gates the model-level impact.

    Args:
        ids:           (b, k) int32 per-field local ids.
        cache:         (C, d) int8 hot-row copies.
        cache_scale:   (C, 1) fp32 per-row scales.
        backing:       (N, d) int8 full mega-table.
        backing_scale: (N, 1) fp32 per-row scales.
        slot_of_row:   (N,) int32 cache slot per global row, -1 = uncached.
        offsets:       (k,) int32 starting row of each table.

    Returns:
        (b, k*d) float32 embedding output.
    """
    b, k = ids.shape
    d = backing.shape[1]
    if interpret is None:
        interpret = not on_tpu()
    if strategy == "auto":
        strategy = "pallas" if on_tpu() else "jnp"
    rows = _flat_rows(ids, offsets)
    if strategy == "jnp":
        out = ref.ref_two_level_gather_q8(rows, slot_of_row, cache,
                                          cache_scale, backing, backing_scale)
    elif strategy == "pallas":
        slots = jnp.take(slot_of_row, rows, axis=0)
        out = mtl_gather_two_level_q8(rows, slots, cache, cache_scale,
                                      backing, backing_scale,
                                      interpret=interpret)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    return out.reshape(b, k * d)


def multi_table_lookup_cached_q8_multihot(ids: jax.Array, mask: jax.Array,
                                          cache: jax.Array,
                                          cache_scale: jax.Array,
                                          backing: jax.Array,
                                          backing_scale: jax.Array,
                                          slot_of_row: jax.Array,
                                          offsets: jax.Array, *,
                                          strategy: str = "auto",
                                          interpret: bool | None = None
                                          ) -> jax.Array:
    """Multi-hot (pooled) quantized tiered lookup.

    Masked slots redirect to the mega-table's zero row exactly as in the
    fp32 path — the zero row's int8 payload is 0, so it dequantizes to an
    exact 0.0 under any scale and pooling stays correct. Pooling happens
    in fp32 *after* per-row dequant (inside the kernel), never in int8.

    Args:
        ids:           (b, k, h) local ids; invalid slots arbitrary.
        mask:          (b, k, h) 1 for valid slots, 0 otherwise.
        cache:         (C, d) int8 hot-row copies.
        cache_scale:   (C, 1) fp32 per-row scales.
        backing:       (N, d) int8 mega-table **with a trailing zero row**.
        backing_scale: (N, 1) fp32 per-row scales.
        slot_of_row:   (N,) int32 index map.
        offsets:       (k,) table starts.

    Returns:
        (b, k*d) float32 pooled output.
    """
    b, k, h = ids.shape
    d = backing.shape[1]
    if interpret is None:
        interpret = not on_tpu()
    if strategy == "auto":
        strategy = "pallas" if on_tpu() else "jnp"
    zero_row = backing.shape[0] - 1
    rows = ids.astype(jnp.int32) + offsets[None, :, None].astype(jnp.int32)
    rows = jnp.where(mask.astype(bool), rows, zero_row).reshape(-1)
    if strategy == "jnp":
        vals = ref.ref_two_level_gather_q8(rows, slot_of_row, cache,
                                           cache_scale, backing,
                                           backing_scale)
        pooled = jnp.sum(vals.reshape(b, k, h, d)
                         * mask[..., None].astype(vals.dtype), axis=2)
        return pooled.reshape(b, k * d)
    if strategy == "pallas":
        slots = jnp.take(slot_of_row, rows, axis=0)
        out = mtl_gather_two_level_q8(rows, slots, cache, cache_scale,
                                      backing, backing_scale, hot=h,
                                      interpret=interpret)
        return out.reshape(b, k * d)
    raise ValueError(f"unknown strategy {strategy!r}")


def multi_table_lookup_host(ids: jax.Array, cache: jax.Array,
                            staging: jax.Array, slot_of_row: jax.Array,
                            staging_slot_of_row: jax.Array,
                            offsets: jax.Array, *, strategy: str = "auto",
                            interpret: bool | None = None) -> jax.Array:
    """Fused lookup through an out-of-HBM (cache + staging) store.

    The HostBackedStore analogue of :func:`multi_table_lookup_cached` with
    no device backing operand: cached rows from ``cache``, this batch's
    staged misses from ``staging``, anything else zero (the guard — the
    serve path stages every miss first, so the guard never fires on a
    correctly staged batch). Bit-exact with the dense path because both
    tiers hold verbatim backing-row copies.

    Args:
        ids:                 (b, k) int32 per-field local ids.
        cache:               (C, d) hot-row copies.
        staging:             (S, d) staged miss rows of this batch.
        slot_of_row:         (N,) int32 cache slot per row, -1 = uncached.
        staging_slot_of_row: (N,) int32 staging slot per row, -1 = unstaged.
        offsets:             (k,) int32 starting row of each table.

    Returns:
        (b, k*d) embedding output.
    """
    b, k = ids.shape
    d = cache.shape[1]
    if interpret is None:
        interpret = not on_tpu()
    if strategy == "auto":
        strategy = "pallas" if on_tpu() else "jnp"
    rows = _flat_rows(ids, offsets)
    if strategy == "jnp":
        out = ref.ref_three_level_gather(rows, slot_of_row,
                                         staging_slot_of_row, cache, staging)
    elif strategy == "pallas":
        cslots = jnp.take(slot_of_row, rows, axis=0)
        sslots = jnp.take(staging_slot_of_row, rows, axis=0)
        out = mtl_gather_three_level(cslots, sslots, cache, staging,
                                     interpret=interpret)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    return out.reshape(b, k * d)


def multi_table_lookup_host_multihot(ids: jax.Array, mask: jax.Array,
                                     cache: jax.Array, staging: jax.Array,
                                     slot_of_row: jax.Array,
                                     staging_slot_of_row: jax.Array,
                                     offsets: jax.Array, *,
                                     strategy: str = "auto",
                                     interpret: bool | None = None
                                     ) -> jax.Array:
    """Multi-hot (pooled) fused lookup through an out-of-HBM store.

    Mirrors :func:`multi_table_lookup_cached_multihot`: the jnp path masks
    after the three-level gather, the pallas path redirects masked slots
    to the mega-table's zero row — which pools zero from *any* tier, since
    the zero row's value is zero in the backing and every tier holds
    verbatim copies (and the zero-guard returns zero when it is in none).

    Args:
        ids:                 (b, k, h) local ids; invalid slots arbitrary.
        mask:                (b, k, h) 1 for valid slots, 0 otherwise.
        cache:               (C, d) hot-row copies.
        staging:             (S, d) staged miss rows of this batch.
        slot_of_row:         (N,) int32 cache index map.
        staging_slot_of_row: (N,) int32 staging index map.
        offsets:             (k,) table starts.

    Returns:
        (b, k*d) pooled output.
    """
    b, k, h = ids.shape
    d = cache.shape[1]
    if interpret is None:
        interpret = not on_tpu()
    if strategy == "auto":
        strategy = "pallas" if on_tpu() else "jnp"
    zero_row = slot_of_row.shape[0] - 1
    rows = ids.astype(jnp.int32) + offsets[None, :, None].astype(jnp.int32)
    rows = jnp.where(mask.astype(bool), rows, zero_row).reshape(-1)
    if strategy == "jnp":
        vals = ref.ref_three_level_gather(rows, slot_of_row,
                                          staging_slot_of_row, cache, staging)
        pooled = jnp.sum(vals.reshape(b, k, h, d)
                         * mask.reshape(b, k, h, 1).astype(cache.dtype),
                         axis=2)
        return pooled.reshape(b, k * d)
    if strategy == "pallas":
        cslots = jnp.take(slot_of_row, rows, axis=0)
        sslots = jnp.take(staging_slot_of_row, rows, axis=0)
        out = mtl_gather_three_level(cslots, sslots, cache, staging, hot=h,
                                     interpret=interpret)
        return out.reshape(b, k * d)
    raise ValueError(f"unknown strategy {strategy!r}")


def multi_table_lookup_host_q8(ids: jax.Array, cache: jax.Array,
                               cache_scale: jax.Array, staging: jax.Array,
                               staging_scale: jax.Array,
                               slot_of_row: jax.Array,
                               staging_slot_of_row: jax.Array,
                               offsets: jax.Array, *, strategy: str = "auto",
                               interpret: bool | None = None) -> jax.Array:
    """Quantized out-of-HBM lookup: int8 cache/staging rows, fp32 scales,
    in-gather dequant, zero-guard intact (q = 0 dequantizes to 0.0).

    The int8 twin of :func:`multi_table_lookup_host`; the serve path's
    staging contract is unchanged — every miss must be staged before the
    lookup, only the bytes staged per row shrink to ``d + 4``.

    Args:
        ids:                 (b, k) int32 per-field local ids.
        cache:               (C, d) int8 hot-row copies.
        cache_scale:         (C, 1) fp32 per-row scales.
        staging:             (S, d) int8 staged miss rows.
        staging_scale:       (S, 1) fp32 per-row scales.
        slot_of_row:         (N,) int32 cache slot per row, -1 = uncached.
        staging_slot_of_row: (N,) int32 staging slot per row, -1 = unstaged.
        offsets:             (k,) int32 starting row of each table.

    Returns:
        (b, k*d) float32 embedding output.
    """
    b, k = ids.shape
    d = cache.shape[1]
    if interpret is None:
        interpret = not on_tpu()
    if strategy == "auto":
        strategy = "pallas" if on_tpu() else "jnp"
    rows = _flat_rows(ids, offsets)
    if strategy == "jnp":
        out = ref.ref_three_level_gather_q8(
            rows, slot_of_row, staging_slot_of_row,
            cache, cache_scale, staging, staging_scale)
    elif strategy == "pallas":
        cslots = jnp.take(slot_of_row, rows, axis=0)
        sslots = jnp.take(staging_slot_of_row, rows, axis=0)
        out = mtl_gather_three_level_q8(cslots, sslots, cache, cache_scale,
                                        staging, staging_scale,
                                        interpret=interpret)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    return out.reshape(b, k * d)


def multi_table_lookup_host_q8_multihot(ids: jax.Array, mask: jax.Array,
                                        cache: jax.Array,
                                        cache_scale: jax.Array,
                                        staging: jax.Array,
                                        staging_scale: jax.Array,
                                        slot_of_row: jax.Array,
                                        staging_slot_of_row: jax.Array,
                                        offsets: jax.Array, *,
                                        strategy: str = "auto",
                                        interpret: bool | None = None
                                        ) -> jax.Array:
    """Multi-hot (pooled) quantized out-of-HBM lookup.

    Masked slots redirect to the zero row; whichever tier holds it (or the
    zero-guard, if neither does) contributes an exact 0.0 because the int8
    payload is 0. Pooling is fp32 post-dequant, as in the cached variant.

    Args:
        ids:                 (b, k, h) local ids; invalid slots arbitrary.
        mask:                (b, k, h) 1 for valid slots, 0 otherwise.
        cache:               (C, d) int8 hot-row copies.
        cache_scale:         (C, 1) fp32 per-row scales.
        staging:             (S, d) int8 staged miss rows.
        staging_scale:       (S, 1) fp32 per-row scales.
        slot_of_row:         (N,) int32 cache index map.
        staging_slot_of_row: (N,) int32 staging index map.
        offsets:             (k,) table starts.

    Returns:
        (b, k*d) float32 pooled output.
    """
    b, k, h = ids.shape
    d = cache.shape[1]
    if interpret is None:
        interpret = not on_tpu()
    if strategy == "auto":
        strategy = "pallas" if on_tpu() else "jnp"
    zero_row = slot_of_row.shape[0] - 1
    rows = ids.astype(jnp.int32) + offsets[None, :, None].astype(jnp.int32)
    rows = jnp.where(mask.astype(bool), rows, zero_row).reshape(-1)
    if strategy == "jnp":
        vals = ref.ref_three_level_gather_q8(
            rows, slot_of_row, staging_slot_of_row,
            cache, cache_scale, staging, staging_scale)
        pooled = jnp.sum(vals.reshape(b, k, h, d)
                         * mask.reshape(b, k, h, 1).astype(vals.dtype),
                         axis=2)
        return pooled.reshape(b, k * d)
    if strategy == "pallas":
        cslots = jnp.take(slot_of_row, rows, axis=0)
        sslots = jnp.take(staging_slot_of_row, rows, axis=0)
        out = mtl_gather_three_level_q8(cslots, sslots, cache, cache_scale,
                                        staging, staging_scale, hot=h,
                                        interpret=interpret)
        return out.reshape(b, k * d)
    raise ValueError(f"unknown strategy {strategy!r}")


# jitted so the epilogue's fp32 multiply-add chain contracts exactly like
# the (always-jitted) pallas kernel's — eager numpy-style evaluation would
# break the bitwise jnp-vs-interpret parity the kernel tests assert
_ref_dense_matmul_q8 = jax.jit(ref.ref_dense_matmul_q8,
                               static_argnames=("relu",))


def dense_matmul_q8(h: jax.Array, wq: jax.Array, wscale: jax.Array,
                    bias: jax.Array, *, relu: bool = True,
                    strategy: str = "auto",
                    interpret: bool | None = None) -> jax.Array:
    """Quantized dense layer: dynamic int8 activations × static int8
    weights, int32 accumulate, dequant + bias (+ ReLU) fused in the
    epilogue.

    The compute twin of the q8 gathers: weights arrive already quantized
    per output channel (once, at plan compile — see
    ``quant.quantize_channels``), activations are quantized per row *here*
    because their range is batch-dependent. Both strategies share that
    quantizer, so pallas-vs-jnp differ only in how the identical int8
    arithmetic is lowered. Not bit-exact with the fp32 GEMM (two absmax
    round-trips); the accuracy-parity benchmark gates the model-level
    impact (``accuracy_parity.py --quant-mlp``).

    Args:
        h:      (b, fan_in) fp32 activations.
        wq:     (fan_in, fan_out) int8 per-channel quantized weights.
        wscale: (1, fan_out) fp32 per-channel weight scales.
        bias:   (fan_out,) fp32.
        relu:   fuse the ReLU epilogue (off for pre-logit layers).

    Returns:
        (b, fan_out) float32 layer output.
    """
    if interpret is None:
        interpret = not on_tpu()
    if strategy == "auto":
        strategy = "pallas" if on_tpu() else "jnp"
    hscale = quant.absmax_scale(h, axis=-1)
    hq = quant.quantize(h, hscale)
    bias2d = bias.reshape(1, -1)
    if strategy == "jnp":
        return _ref_dense_matmul_q8(hq, hscale, wq, wscale, bias2d,
                                    relu=relu)
    if strategy == "pallas":
        return dmm_q8(hq, hscale, wq, wscale, bias2d, relu=relu,
                      interpret=interpret)
    raise ValueError(f"unknown strategy {strategy!r}")


def multi_table_lookup_onehot(ids: jax.Array, stacked_tables: jax.Array, *,
                              interpret: bool | None = None) -> jax.Array:
    """One-hot MXU lookup for small-field groups. Returns (b, k, d)."""
    if interpret is None:
        interpret = not on_tpu()
    return mtl_onehot(ids, stacked_tables, interpret=interpret)


def multi_table_lookup_multihot(ids: jax.Array, mask: jax.Array,
                                mega_table: jax.Array, offsets: jax.Array, *,
                                strategy: str = "auto",
                                interpret: bool | None = None) -> jax.Array:
    """Multi-hot (pooled) fused lookup.

    Args:
        ids:        (b, k, h) local ids; invalid slots arbitrary.
        mask:       (b, k, h) 1 for valid slots, 0 otherwise.
        mega_table: (N, d) concatenated tables **with a trailing all-zero
                    row** at index N-1 (ops appends it in FusedEmbedding).
        offsets:    (k,) table starts.

    Returns:
        (b, k*d) pooled output.
    """
    b, k, h = ids.shape
    d = mega_table.shape[1]
    if interpret is None:
        interpret = not on_tpu()
    if strategy == "auto":
        strategy = "pallas" if on_tpu() else "jnp"
    if strategy == "jnp":
        return ref.ref_multi_hot_lookup(ids, mask, mega_table, offsets)
    if strategy == "pallas":
        zero_row = mega_table.shape[0] - 1
        rows = ids.astype(jnp.int32) + offsets[None, :, None].astype(jnp.int32)
        rows = jnp.where(mask.astype(bool), rows, zero_row).reshape(-1)
        out = mtl_gather_multihot(rows, mega_table, hot=h, interpret=interpret)
        return out.reshape(b, k * d)
    raise ValueError(f"unknown strategy {strategy!r}")
