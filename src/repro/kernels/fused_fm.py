"""Fused factorization-machine second-order kernel (DeepFM, non-GEMM fusion C5).

The FM pairwise-interaction term

    y_fm(b) = 0.5 * Σ_d [ (Σ_k v[b,k,d])² − Σ_k v[b,k,d]² ]

is, un-fused, a chain of square / reduce-sum / subtract ops each writing an
intermediate to HBM. The fused kernel keeps the (bm, k, d) tile VMEM-resident
and emits only the (bm, 1) result — exactly the paper's C5 treatment of
DeepFM's explicit-interaction module.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fm_kernel(v_ref, out_ref):
    v = v_ref[...].astype(jnp.float32)            # (bm, k, d)
    s = jnp.sum(v, axis=1)                        # (bm, d)
    sq = jnp.sum(v * v, axis=1)                   # (bm, d)
    out = 0.5 * jnp.sum(s * s - sq, axis=-1)      # (bm,)
    out_ref[...] = out[:, None].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def fused_fm_second_order(v: jax.Array, *, block_b: int = 128,
                          interpret: bool = False) -> jax.Array:
    """Fused FM 2nd-order term.

    Args:
        v: (b, k, d) field embeddings.

    Returns:
        (b, 1) interaction score (kept 2-D for TPU-friendly layout).
    """
    b, k, d = v.shape
    bm = min(block_b, b)
    grid = (pl.cdiv(b, bm),)
    return pl.pallas_call(
        _fm_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, k, d), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), v.dtype),
        interpret=interpret,
    )(v)
