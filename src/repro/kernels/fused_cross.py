"""Fused elementwise tails of DCN / DCNv2 cross layers (non-GEMM fusion, C5).

The cross layer is ``x_{l+1} = x0 ⊙ f(x_l) + [b] + x_l`` where ``f`` is the
GEMM part (left to the MXU via XLA). Everything after the GEMM is a chain of
small elementwise ops that the paper fuses into one kernel; on TPU we fuse
them into a single VPU pass with one VMEM round-trip instead of three.

  DCNv2:  out = x0 * (x_l W + b) + x_l      (``xw_plus`` = x_l W + b)
  DCNv1:  out = x0 * (x_l · w) + b + x_l    (``xlw`` is (b, 1) per-sample)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cross_v2_kernel(x0_ref, xw_ref, x_ref, out_ref):
    out_ref[...] = x0_ref[...] * xw_ref[...] + x_ref[...]


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def fused_cross_v2(x0: jax.Array, xw_plus: jax.Array, x: jax.Array, *,
                   block_b: int = 256, interpret: bool = False) -> jax.Array:
    """DCNv2 cross tail: ``x0 * xw_plus + x`` in one VMEM pass."""
    b, dim = x0.shape
    bm = min(block_b, b)
    grid = (pl.cdiv(b, bm),)
    spec = pl.BlockSpec((bm, dim), lambda i: (i, 0))
    return pl.pallas_call(
        _cross_v2_kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b, dim), x0.dtype),
        interpret=interpret,
    )(x0, xw_plus, x)


def _cross_v1_kernel(x0_ref, xlw_ref, bias_ref, x_ref, out_ref):
    out_ref[...] = x0_ref[...] * xlw_ref[...] + bias_ref[...] + x_ref[...]


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def fused_cross_v1(x0: jax.Array, xlw: jax.Array, bias: jax.Array,
                   x: jax.Array, *, block_b: int = 256,
                   interpret: bool = False) -> jax.Array:
    """DCNv1 cross tail: ``x0 * xlw + bias + x`` (xlw broadcast from (b,1))."""
    b, dim = x0.shape
    bm = min(block_b, b)
    grid = (pl.cdiv(b, bm),)
    spec = pl.BlockSpec((bm, dim), lambda i: (i, 0))
    return pl.pallas_call(
        _cross_v1_kernel,
        grid=grid,
        in_specs=[
            spec,
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, dim), lambda i: (0, 0)),
            spec,
        ],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b, dim), x0.dtype),
        interpret=interpret,
    )(x0, xlw, bias.reshape(1, dim), x)
