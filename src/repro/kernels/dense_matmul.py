"""Fused int8 dense matmul with in-kernel dequant — the MLP compute tier.

The quantized-compute twin of the MLP GEMMs emitted by
``models/ctr/common.emit_mlp_ops``: int8 activations (per-row scale) ×
int8 weights (per-output-channel scale) accumulate in int32 on the MXU,
and the epilogue — widen to fp32, apply both scales, add bias, optional
ReLU — runs in the same VMEM pass. The fp32 weight matrix never exists at
serve time; the fp32 activation exists only upstream of the per-row
quantizer in the wrapper (``ops.dense_matmul_q8``).

Blocking: one grid axis over batch blocks; the full (fan_in, fan_out)
weight tile rides in VMEM per block — CTR dense layers are a few hundred
units square (≤ ~0.5 MB int8), far under the VMEM budget, so K/N tiling
would only add accumulator plumbing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dmm_q8_kernel(hq_ref, hs_ref, wq_ref, ws_ref, b_ref, out_ref, *,
                   relu: bool):
    # int8 × int8 → int32 on the MXU; both operands stay int8 in VMEM
    acc = jax.lax.dot_general(hq_ref[...], wq_ref[...],
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    # dequant epilogue: row scale × channel scale factorizes the per-element
    # scale grid, so two rank-1 broadcasts undo both quantizers at once
    out = acc.astype(jnp.float32) * hs_ref[...] * ws_ref[...] + b_ref[...]
    if relu:
        out = jnp.maximum(out, 0.0)
    out_ref[...] = out


@functools.partial(jax.jit,
                   static_argnames=("relu", "block_b", "interpret"))
def dmm_q8(hq: jax.Array, hscale: jax.Array, wq: jax.Array,
           wscale: jax.Array, bias: jax.Array, *, relu: bool = True,
           block_b: int = 256, interpret: bool = False) -> jax.Array:
    """Quantized dense layer: ``relu((hq·wq) * hscale * wscale + bias)``.

    Args:
        hq:     (b, fan_in) int8 per-row quantized activations.
        hscale: (b, 1) fp32 per-row activation scales.
        wq:     (fan_in, fan_out) int8 per-channel quantized weights.
        wscale: (1, fan_out) fp32 per-channel weight scales.
        bias:   (1, fan_out) fp32.
        relu:   fuse the ReLU epilogue (off for pre-logit layers).

    Returns:
        (b, fan_out) float32 layer output.
    """
    b, fan_in = hq.shape
    fan_out = wq.shape[1]
    bm = min(block_b, b)
    grid = (pl.cdiv(b, bm),)
    return pl.pallas_call(
        functools.partial(_dmm_q8_kernel, relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, fan_in), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((fan_in, fan_out), lambda i: (0, 0)),
            pl.BlockSpec((1, fan_out), lambda i: (0, 0)),
            pl.BlockSpec((1, fan_out), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, fan_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, fan_out), jnp.float32),
        interpret=interpret,
    )(hq, hscale, wq, wscale, bias)
