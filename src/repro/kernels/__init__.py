"""Pallas TPU kernels for DPIFrame hot spots, with pure-jnp oracles.

  multi_table_lookup.py  fused embedding gather (paper Alg. 1)  [C2, C3]
  fused_cross.py         DCN/DCNv2 cross elementwise tails      [C5]
  fused_fm.py            DeepFM FM 2nd-order term               [C5]
  dense_matmul.py        int8 MLP matmul with fused dequant epilogue
  ops.py                 public wrappers + strategy dispatch
  ref.py                 reference oracles (incl. literal Alg. 1)
"""

from .ops import (
    dense_matmul_q8,
    fused_cross_v1,
    fused_cross_v2,
    fused_fm_second_order,
    multi_table_lookup,
    multi_table_lookup_cached,
    multi_table_lookup_cached_multihot,
    multi_table_lookup_multihot,
    multi_table_lookup_onehot,
    on_tpu,
)

__all__ = [
    "dense_matmul_q8",
    "fused_cross_v1",
    "fused_cross_v2",
    "fused_fm_second_order",
    "multi_table_lookup",
    "multi_table_lookup_cached",
    "multi_table_lookup_cached_multihot",
    "multi_table_lookup_multihot",
    "multi_table_lookup_onehot",
    "on_tpu",
]
