"""Pallas TPU kernels for DPIFrame's multi-table embedding lookup (Alg. 1).

TPU adaptation of the paper's GPU design (DESIGN.md §2):

* GPU: one CUDA *thread* per output element, output-first allocation so a
  warp's 32 threads write coalesced addresses.
* TPU: one Pallas *program* per output row. The k per-field tables are
  concatenated into a single HBM-resident mega-table; the per-program block
  to fetch is selected by a ``PrefetchScalarGridSpec`` index_map that reads
  the (scalar-prefetched) global row id — this is the TPU analogue of the
  in-thread ``emb_row`` computation in Alg. 1 lines 6–8. Output blocks map
  1:1 to grid steps, so writes are perfectly sequential (output-first, C3).

Three production variants + one strawman:

  ``mtl_gather``       output-first row gather (the paper's algorithm).
  ``mtl_gather_multihot`` same, pooling h hot ids per field via output-block
                       revisiting across the innermost grid axis.
  ``mtl_onehot``       TPU-only alternative with *no GPU analogue*: small
                       fields are batched into a dense ``one_hot(ids) @ table``
                       executed on the MXU — turns the irregular gather into
                       a systolic matmul (used by ops.py for fields whose
                       table fits VMEM).
  ``mtl_input_first``  the paper's Fig.-11 strawman: grid ordered by *input*
                       (field-major output layout) so consecutive programs
                       write strided addresses; needs a final transpose pass.

All kernels are validated in ``interpret=True`` mode against
``repro.kernels.ref`` oracles (tests/test_kernels.py).

NOTE on tiling: blocks here are (1, d). On a real v5e the fp32 minimum tile
is (8, 128); production would sort ids and batch 8 rows per program — the
(1, d) form keeps the algorithm exact for arbitrary d and is what we can
validate on CPU. The roofline accounting in analysis/ uses the HBM-bytes
model, which is tiling-independent.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# Output-first fused gather (the paper's Algorithm 1, C2 + C3)
# ---------------------------------------------------------------------------

def _copy_row_kernel(ids_ref, table_ref, out_ref):
    # ids_ref is the scalar-prefetch operand; the gather itself already
    # happened in the BlockSpec index_map, so the body is a VMEM row copy.
    del ids_ref
    out_ref[...] = table_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def mtl_gather(flat_rows: jax.Array, mega_table: jax.Array, *,
               interpret: bool = False) -> jax.Array:
    """Output-first fused multi-table gather.

    Args:
        flat_rows:  (R,) int32 *global* row ids into the mega-table
                    (= per-field id + table offset, precomputed).
        mega_table: (N, d) all tables concatenated along rows.

    Returns:
        (R, d) gathered rows; caller reshapes (b*k, d) -> (b, k*d).
    """
    r = flat_rows.shape[0]
    d = mega_table.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(r,),
        in_specs=[pl.BlockSpec((1, d), lambda p, ids: (ids[p], 0))],
        out_specs=pl.BlockSpec((1, d), lambda p, ids: (p, 0)),
    )
    return pl.pallas_call(
        _copy_row_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, d), mega_table.dtype),
        interpret=interpret,
    )(flat_rows, mega_table)


# ---------------------------------------------------------------------------
# Multi-hot pooling variant (sequence features, Alg. 1 "offset information")
# ---------------------------------------------------------------------------

def _pool_row_kernel(ids_ref, table_ref, out_ref):
    del ids_ref
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = table_ref[...]

    @pl.when(j > 0)
    def _acc():
        out_ref[...] += table_ref[...]


@functools.partial(jax.jit, static_argnames=("hot", "interpret"))
def mtl_gather_multihot(flat_rows: jax.Array, mega_table: jax.Array, *,
                        hot: int, interpret: bool = False) -> jax.Array:
    """Pooled (sum) gather of ``hot`` ids per output row.

    Invalid slots must be pre-redirected to an all-zero row of the mega-table
    (ops.py appends one), which realizes the 0/1 validity mask without any
    in-kernel branching — masking by address, the TPU-friendly form.

    Args:
        flat_rows:  (R*hot,) int32 global rows, row-major per output row.
        mega_table: (N, d), last row all-zero.

    Returns:
        (R, d) pooled rows.
    """
    rh = flat_rows.shape[0]
    r = rh // hot
    d = mega_table.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(r, hot),
        in_specs=[pl.BlockSpec((1, d), lambda p, j, ids: (ids[p * hot + j], 0))],
        out_specs=pl.BlockSpec((1, d), lambda p, j, ids: (p, 0)),
    )
    return pl.pallas_call(
        _pool_row_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, d), mega_table.dtype),
        interpret=interpret,
    )(flat_rows, mega_table)


# ---------------------------------------------------------------------------
# Two-level (cache + backing) gather — the CachedStore lookup
# ---------------------------------------------------------------------------

def _two_level_kernel(slots_ref, rows_ref, cache_ref, backing_ref, out_ref):
    # Which tier holds this row was decided by the scalar-prefetched slot
    # map; both index maps already point at the right block (misses pin the
    # cache block to slot 0, hits pin the backing block to row 0 — the
    # wrong-tier fetch is always the same hot line, not a wasted HBM row).
    del rows_ref
    p = pl.program_id(0)
    hot = pl.num_programs(1)
    j = pl.program_id(1)
    hit = slots_ref[p * hot + j] >= 0
    val = jnp.where(hit, cache_ref[...], backing_ref[...])

    @pl.when(j == 0)
    def _init():
        out_ref[...] = val

    @pl.when(j > 0)
    def _acc():
        out_ref[...] += val


@functools.partial(jax.jit, static_argnames=("hot", "interpret"))
def mtl_gather_two_level(flat_rows: jax.Array, slots: jax.Array,
                         cache: jax.Array, backing: jax.Array, *,
                         hot: int = 1, interpret: bool = False) -> jax.Array:
    """Two-level gather: cache hits from the hot-row cache, misses from the
    backing table, pooled over ``hot`` ids per output row (hot=1 = plain
    gather, the one-hot path).

    Both the slot and the row are scalar-prefetched, so tier selection
    happens in the BlockSpec index maps — the TPU analogue of HugeCTR's
    address-indirection through the inference parameter server's hashmap,
    with no divergent branching in the kernel body.

    Args:
        flat_rows: (R*hot,) int32 global rows into ``backing``.
        slots:     (R*hot,) int32 cache slot per row, -1 = not cached
                   (= ``slot_of_row[flat_rows]``, pre-gathered outside).
        cache:     (C, d) hot-row copies.
        backing:   (N, d) full mega-table.

    Returns:
        (R, d) gathered (hot=1) or sum-pooled (hot>1) rows.
    """
    rh = flat_rows.shape[0]
    r = rh // hot
    d = backing.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(r, hot),
        in_specs=[
            # hit: the row's cache slot; miss: slot 0 (discarded in-body)
            pl.BlockSpec((1, d), lambda p, j, slots, rows:
                         (jnp.maximum(slots[p * hot + j], 0), 0)),
            # miss: the backing row; hit: row 0 (discarded in-body)
            pl.BlockSpec((1, d), lambda p, j, slots, rows:
                         (jnp.where(slots[p * hot + j] >= 0, 0,
                                    rows[p * hot + j]), 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda p, j, slots, rows: (p, 0)),
    )
    return pl.pallas_call(
        _two_level_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, d), backing.dtype),
        interpret=interpret,
    )(slots, flat_rows, cache, backing)


# ---------------------------------------------------------------------------
# Quantized two-level gather — the int8 CachedStore lookup
# ---------------------------------------------------------------------------

def _two_level_q8_kernel(slots_ref, rows_ref, cache_ref, cscale_ref,
                         backing_ref, bscale_ref, out_ref):
    # Same tier selection as the fp32 kernel — the scalar-prefetched index
    # maps already fetched the winning tier's int8 row *and its (1, 1) fp32
    # scale* (the scale rides the identical index map, so picking the tier
    # picks both). Dequantization is one multiply in registers: the fp32
    # row never exists in memory.
    del rows_ref
    p = pl.program_id(0)
    hot = pl.num_programs(1)
    j = pl.program_id(1)
    hit = slots_ref[p * hot + j] >= 0
    q = jnp.where(hit, cache_ref[...], backing_ref[...]).astype(jnp.float32)
    s = jnp.where(hit, cscale_ref[...], bscale_ref[...])
    val = q * s

    @pl.when(j == 0)
    def _init():
        out_ref[...] = val

    @pl.when(j > 0)
    def _acc():
        out_ref[...] += val


@functools.partial(jax.jit, static_argnames=("hot", "interpret"))
def mtl_gather_two_level_q8(flat_rows: jax.Array, slots: jax.Array,
                            cache: jax.Array, cache_scale: jax.Array,
                            backing: jax.Array, backing_scale: jax.Array, *,
                            hot: int = 1, interpret: bool = False
                            ) -> jax.Array:
    """Quantized two-level gather with in-kernel dequantization.

    The int8 variant of :func:`mtl_gather_two_level`: both tiers hold int8
    rows plus an ``(N, 1)`` fp32 scale column, and each scale BlockSpec
    reuses its tier's row index map — HBM moves ``d + 4`` bytes per row
    instead of ``4·d``. The body dequantizes the selected row
    (``q.astype(f32) * scale``) before the pooled accumulate, so multi-hot
    pooling happens in fp32 (int8 sums would overflow and compound error).

    Args:
        flat_rows:     (R*hot,) int32 global rows into ``backing``.
        slots:         (R*hot,) int32 cache slot per row, -1 = not cached.
        cache:         (C, d) int8 hot-row copies.
        cache_scale:   (C, 1) fp32 per-row scales of the cache tier.
        backing:       (N, d) int8 full mega-table.
        backing_scale: (N, 1) fp32 per-row scales of the backing tier.

    Returns:
        (R, d) float32 dequantized (hot=1) or sum-pooled (hot>1) rows.
    """
    rh = flat_rows.shape[0]
    r = rh // hot
    d = backing.shape[1]
    cache_idx = lambda p, j, slots, rows: (jnp.maximum(slots[p * hot + j],
                                                       0), 0)
    backing_idx = lambda p, j, slots, rows: (
        jnp.where(slots[p * hot + j] >= 0, 0, rows[p * hot + j]), 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(r, hot),
        in_specs=[
            pl.BlockSpec((1, d), cache_idx),
            pl.BlockSpec((1, 1), cache_idx),     # scale rides the row's map
            pl.BlockSpec((1, d), backing_idx),
            pl.BlockSpec((1, 1), backing_idx),
        ],
        out_specs=pl.BlockSpec((1, d), lambda p, j, slots, rows: (p, 0)),
    )
    return pl.pallas_call(
        _two_level_q8_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, d), jnp.float32),
        interpret=interpret,
    )(slots, flat_rows, cache, cache_scale, backing, backing_scale)


# ---------------------------------------------------------------------------
# Three-level (cache / staging / zero-guard) gather — the HostBackedStore
# lookup
# ---------------------------------------------------------------------------

def _three_level_kernel(cslots_ref, sslots_ref, cache_ref, staging_ref,
                        out_ref):
    # Tier selection happened in the index maps; the body picks which of
    # the two fetched VMEM rows (or zero) survives. There is no backing
    # operand at all — rows resolved by neither tier gather zero, and the
    # serve path's staging contract makes that case unreachable on a
    # correctly staged batch.
    p = pl.program_id(0)
    hot = pl.num_programs(1)
    j = pl.program_id(1)
    cache_hit = cslots_ref[p * hot + j] >= 0
    stage_hit = sslots_ref[p * hot + j] >= 0
    val = jnp.where(cache_hit, cache_ref[...],
                    jnp.where(stage_hit, staging_ref[...],
                              jnp.zeros_like(cache_ref[...])))

    @pl.when(j == 0)
    def _init():
        out_ref[...] = val

    @pl.when(j > 0)
    def _acc():
        out_ref[...] += val


@functools.partial(jax.jit, static_argnames=("hot", "interpret"))
def mtl_gather_three_level(cslots: jax.Array, sslots: jax.Array,
                           cache: jax.Array, staging: jax.Array, *,
                           hot: int = 1, interpret: bool = False
                           ) -> jax.Array:
    """Three-level gather: cache hits from the hot-row cache, staged misses
    from the per-batch staging buffer, anything else zero (the guard),
    pooled over ``hot`` ids per output row.

    The out-of-HBM variant of :func:`mtl_gather_two_level`: the backing
    table lives in *host* memory and never appears as an operand — the
    host-side prefetch pipeline copies each batch's miss rows into
    ``staging`` before the call. Both slot maps are scalar-prefetched, so
    tier selection stays in the BlockSpec index maps (the wrong-tier fetch
    is pinned to block 0 — a hot line, not a wasted HBM row) and the body
    is a branch-free double select.

    Args:
        cslots:  (R*hot,) int32 cache slot per row, -1 = not cached.
        sslots:  (R*hot,) int32 staging slot per row, -1 = not staged.
        cache:   (C, d) hot-row copies.
        staging: (S, d) this batch's staged miss rows.

    Returns:
        (R, d) gathered (hot=1) or sum-pooled (hot>1) rows.
    """
    rh = cslots.shape[0]
    r = rh // hot
    d = cache.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(r, hot),
        in_specs=[
            # cache hit: the row's cache slot; otherwise slot 0 (discarded)
            pl.BlockSpec((1, d), lambda p, j, cslots, sslots:
                         (jnp.maximum(cslots[p * hot + j], 0), 0)),
            # staged miss: the row's staging slot; otherwise slot 0
            pl.BlockSpec((1, d), lambda p, j, cslots, sslots:
                         (jnp.maximum(sslots[p * hot + j], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda p, j, cslots, sslots: (p, 0)),
    )
    return pl.pallas_call(
        _three_level_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, d), cache.dtype),
        interpret=interpret,
    )(cslots, sslots, cache, staging)


# ---------------------------------------------------------------------------
# Quantized three-level gather — the int8 HostBackedStore lookup
# ---------------------------------------------------------------------------

def _three_level_q8_kernel(cslots_ref, sslots_ref, cache_ref, cscale_ref,
                           staging_ref, sscale_ref, out_ref):
    # Double select on the int8 payload (zero-guard included: a row in
    # neither tier dequantizes from q = 0, so any scale multiplies to an
    # exact 0.0), single select on the scale, one dequant multiply.
    p = pl.program_id(0)
    hot = pl.num_programs(1)
    j = pl.program_id(1)
    cache_hit = cslots_ref[p * hot + j] >= 0
    stage_hit = sslots_ref[p * hot + j] >= 0
    q = jnp.where(cache_hit, cache_ref[...],
                  jnp.where(stage_hit, staging_ref[...],
                            jnp.zeros_like(cache_ref[...]))
                  ).astype(jnp.float32)
    s = jnp.where(cache_hit, cscale_ref[...], sscale_ref[...])
    val = q * s

    @pl.when(j == 0)
    def _init():
        out_ref[...] = val

    @pl.when(j > 0)
    def _acc():
        out_ref[...] += val


@functools.partial(jax.jit, static_argnames=("hot", "interpret"))
def mtl_gather_three_level_q8(cslots: jax.Array, sslots: jax.Array,
                              cache: jax.Array, cache_scale: jax.Array,
                              staging: jax.Array, staging_scale: jax.Array,
                              *, hot: int = 1, interpret: bool = False
                              ) -> jax.Array:
    """Quantized three-level gather with in-kernel dequantization.

    The int8 variant of :func:`mtl_gather_three_level`: cache and staging
    hold int8 rows with ``(·, 1)`` fp32 scale columns whose BlockSpecs
    reuse the row index maps, so the host→device staging path and the
    device gather both move ``d + 4`` bytes per row. Rows in neither tier
    keep the zero-guard: the int8 payload selects to 0, and 0 times any
    scale is exactly 0.0.

    Args:
        cslots:        (R*hot,) int32 cache slot per row, -1 = not cached.
        sslots:        (R*hot,) int32 staging slot per row, -1 = not staged.
        cache:         (C, d) int8 hot-row copies.
        cache_scale:   (C, 1) fp32 per-row scales of the cache tier.
        staging:       (S, d) int8 staged miss rows.
        staging_scale: (S, 1) fp32 per-row scales of the staging tier.

    Returns:
        (R, d) float32 dequantized (hot=1) or sum-pooled (hot>1) rows.
    """
    rh = cslots.shape[0]
    r = rh // hot
    d = cache.shape[1]
    cache_idx = lambda p, j, cslots, sslots: (
        jnp.maximum(cslots[p * hot + j], 0), 0)
    staging_idx = lambda p, j, cslots, sslots: (
        jnp.maximum(sslots[p * hot + j], 0), 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(r, hot),
        in_specs=[
            pl.BlockSpec((1, d), cache_idx),
            pl.BlockSpec((1, 1), cache_idx),
            pl.BlockSpec((1, d), staging_idx),
            pl.BlockSpec((1, 1), staging_idx),
        ],
        out_specs=pl.BlockSpec((1, d), lambda p, j, cslots, sslots: (p, 0)),
    )
    return pl.pallas_call(
        _three_level_q8_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, d), jnp.float32),
        interpret=interpret,
    )(cslots, sslots, cache, cache_scale, staging, staging_scale)


# ---------------------------------------------------------------------------
# One-hot MXU variant (TPU-only; no GPU analogue)
# ---------------------------------------------------------------------------

def _onehot_kernel(ids_ref, table_ref, out_ref):
    # ids_ref:   (bm, 1) int32 local ids for this (batch-tile, field)
    # table_ref: (1, n_pad, d) this field's (padded) table
    # out_ref:   (bm, 1, d)
    n_pad = table_ref.shape[1]
    ids = ids_ref[...]                                        # (bm, 1)
    iota = jax.lax.broadcasted_iota(jnp.int32, (ids.shape[0], n_pad), 1)
    onehot = (iota == ids).astype(table_ref.dtype)            # (bm, n_pad)
    # MXU matmul: (bm, n_pad) @ (n_pad, d)
    out = jnp.dot(onehot, table_ref[0], preferred_element_type=jnp.float32)
    out_ref[...] = out[:, None, :].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def mtl_onehot(ids: jax.Array, stacked_tables: jax.Array, *,
               block_b: int = 128, interpret: bool = False) -> jax.Array:
    """Dense one-hot matmul lookup for small fields.

    Args:
        ids:            (b, k) int32 local ids (each < n_pad).
        stacked_tables: (k, n_pad, d) small tables padded to a common height.

    Returns:
        (b, k, d) embedding output.
    """
    b, k = ids.shape
    _, n_pad, d = stacked_tables.shape
    bm = min(block_b, b)
    grid = (pl.cdiv(b, bm), k)
    return pl.pallas_call(
        _onehot_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 1), lambda i, f: (i, f)),
            pl.BlockSpec((1, n_pad, d), lambda i, f: (f, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1, d), lambda i, f: (i, f, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k, d), stacked_tables.dtype),
        interpret=interpret,
    )(ids, stacked_tables)


# ---------------------------------------------------------------------------
# Input-first strawman (paper Fig. 11 ablation)
# ---------------------------------------------------------------------------

def _copy_row_3d_kernel(ids_ref, table_ref, out_ref):
    del ids_ref
    out_ref[...] = table_ref[...][None]


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def mtl_input_first(flat_rows: jax.Array, mega_table: jax.Array, *,
                    k: int, interpret: bool = False) -> jax.Array:
    """Input-first allocation: programs ordered by input sample.

    Consecutive programs write to a *field-major* (k, b, d) output — a
    stride of b·d elements between successive writes (the TPU reflection of
    the GPU's uncoalesced-warp penalty) — and a final transpose pass
    restores (b, k*d). Exists only to reproduce the Fig.-11 comparison.
    """
    r = flat_rows.shape[0]
    b = r // k
    d = mega_table.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, k),                       # input-sample-major traversal
        in_specs=[pl.BlockSpec((1, d), lambda s, f, ids: (ids[s * k + f], 0))],
        # field-major output: consecutive inner steps jump b rows apart
        out_specs=pl.BlockSpec((1, 1, d), lambda s, f, ids: (f, s, 0)),
    )
    out_fmajor = pl.pallas_call(
        _copy_row_3d_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((k, b, d), mega_table.dtype),
        interpret=interpret,
    )(flat_rows, mega_table)
    # the extra reorganization pass input-first designs pay for:
    return jnp.transpose(out_fmajor, (1, 0, 2)).reshape(b, k * d)
