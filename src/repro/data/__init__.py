"""Synthetic CTR data (Avazu/Criteo schemas) + sharded pipeline."""

from .pipeline import CTRLoader
from .synthetic import AVAZU, CRITEO, DatasetSchema, make_schema, synthetic_batch

__all__ = ["CTRLoader", "AVAZU", "CRITEO", "DatasetSchema", "make_schema",
           "synthetic_batch"]
