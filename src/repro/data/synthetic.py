"""Synthetic Avazu/Criteo-schema CTR data with a planted logistic target.

The real Kaggle datasets are not available offline; we generate id streams
with the *schemas* of Avazu (24 fields) and Criteo (39 fields, of which 26
categorical + 13 bucketized-numeric treated as categorical — the standard
FuxiCTR preprocessing), with heavy-tailed per-field cardinalities matching
the published statistics' orders of magnitude (a few fields in the millions,
most small). A planted logistic ground truth makes AUC/LogLoss meaningful:
each (field, id) has a hidden effect; labels are Bernoulli(σ(Σ effects)).

Everything is **step-indexed and deterministic**: batch(step) is a pure
function of (seed, step), which is what makes checkpoint/restart replay
exact (fault tolerance) and removes host-side data-pipeline stragglers.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

__all__ = ["DatasetSchema", "AVAZU", "CRITEO", "synthetic_batch",
           "make_schema", "zipf_ids"]


@dataclasses.dataclass(frozen=True)
class DatasetSchema:
    name: str
    field_sizes: tuple[int, ...]
    seed: int = 0

    @property
    def k(self) -> int:
        return len(self.field_sizes)

    def scaled(self, max_field: int) -> "DatasetSchema":
        """Cap per-field cardinality (small-memory test variant)."""
        return DatasetSchema(
            name=f"{self.name}-cap{max_field}",
            field_sizes=tuple(min(n, max_field) for n in self.field_sizes),
            seed=self.seed)


def _heavy_tail_sizes(k: int, big: list[int], seed: int) -> tuple[int, ...]:
    """A few huge fields + many small ones (log-uniform 2..10k)."""
    rng = np.random.default_rng(seed)
    sizes = np.exp(rng.uniform(np.log(2), np.log(10_000), size=k)).astype(int)
    sizes = np.maximum(sizes, 2)
    for i, n in enumerate(big):
        sizes[i * (k // max(len(big), 1)) % k] = n
    return tuple(int(s) for s in sizes)


# Published field counts: Avazu 24 fields, Criteo 39 fields.
AVAZU = DatasetSchema(
    name="avazu",
    field_sizes=_heavy_tail_sizes(24, big=[2_000_000, 500_000, 8_000], seed=11),
    seed=11)

CRITEO = DatasetSchema(
    name="criteo",
    field_sizes=_heavy_tail_sizes(39, big=[5_000_000, 1_300_000, 300_000, 10_000],
                                  seed=7),
    seed=7)


def make_schema(name: str, k: int, n_per_field: int, seed: int = 0
                ) -> DatasetSchema:
    """Uniform schema for sensitivity sweeps (paper §V-F)."""
    return DatasetSchema(name=name, field_sizes=(n_per_field,) * k, seed=seed)


def zipf_ids(key: jax.Array, batch: int, field_sizes: tuple[int, ...],
             exponent: float = 1.1) -> jax.Array:
    """Zipf-skewed per-field ids: P(id = r) ∝ (r+1)^-exponent, id < n_i.

    Real CTR id traffic is zipfian (the premise of HugeCTR-style hot-row
    caching); the old generator only had the mild "square the uniform"
    skew. Sampling is inverse-CDF on the continuous bounded power law —
    exact for exponent=1 (``x = n^u``), the standard continuous surrogate
    otherwise — so it is O(batch·k), vectorized, and deterministic in
    ``key`` (no per-field cdf tables over multi-million vocabularies).

    Args:
        key: PRNG key.
        batch: number of samples b.
        field_sizes: per-field vocabulary sizes (k,).
        exponent: zipf s; larger = heavier head (1.0–2.0 typical). 0 is
            valid and gives uniform traffic.

    Returns:
        (b, k) int32 ids, field i in [0, field_sizes[i]).
    """
    sizes = jnp.asarray(field_sizes, dtype=jnp.float32)[None, :]
    u = jax.random.uniform(key, (batch, len(field_sizes)))
    s = float(exponent)
    if abs(s - 1.0) < 1e-9:
        x = jnp.power(sizes, u)                      # cdf ∝ log x
    else:
        # inverse of F(x) = (x^(1-s) - 1) / (n^(1-s) - 1) on [1, n]
        x = jnp.power(1.0 + u * (jnp.power(sizes, 1.0 - s) - 1.0),
                      1.0 / (1.0 - s))
    ids = jnp.floor(x).astype(jnp.int32) - 1
    return jnp.clip(ids, 0, jnp.asarray(field_sizes, jnp.int32)[None, :] - 1)


def _planted_effect(ids: jax.Array, field_sizes: jax.Array) -> jax.Array:
    """Hidden per-(field, id) logit effects — cheap hash-based surrogate.

    Deterministic, wide-spectrum function of the id so nearby ids decorrelate;
    scaled so the sum over k fields lands in a reasonable logit range.
    """
    k = ids.shape[-1]
    f = jnp.arange(k, dtype=jnp.float32)
    phase = ids.astype(jnp.float32) * (0.618033988 + 0.1 * f)[None, :]
    effects = jnp.sin(phase * 12.9898) + 0.5 * jnp.cos(phase * 78.233)
    return jnp.sum(effects, axis=-1) / jnp.sqrt(jnp.asarray(k, jnp.float32))


def synthetic_batch(schema: DatasetSchema, step: int, batch: int,
                    *, seed: int | None = None, skew: str = "quadratic",
                    zipf_exponent: float = 1.1) -> dict[str, jax.Array]:
    """Pure function (schema, step) -> {ids (b,k) int32, labels (b,) f32}.

    ``skew`` selects the id popularity profile:
      "quadratic"  square the uniform — the original mild low-id skew
                   (default; byte-identical to the pre-zipf generator).
      "uniform"    no skew (worst case for any hot-row cache).
      "zipf"       bounded zipf with ``zipf_exponent`` (cache-benchmark
                   traffic; heavier exponent = hotter head).
    """
    seed = schema.seed if seed is None else seed
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k_ids, k_lab = jax.random.split(key)
    sizes = jnp.asarray(schema.field_sizes, dtype=jnp.int32)
    if skew == "quadratic":
        u = jax.random.uniform(k_ids, (batch, schema.k))
        ids = jnp.minimum((u * u * sizes[None, :]).astype(jnp.int32),
                          sizes - 1)
    elif skew == "uniform":
        u = jax.random.uniform(k_ids, (batch, schema.k))
        ids = jnp.minimum((u * sizes[None, :]).astype(jnp.int32), sizes - 1)
    elif skew == "zipf":
        ids = zipf_ids(k_ids, batch, schema.field_sizes,
                       exponent=zipf_exponent)
    else:
        raise ValueError(f"unknown skew {skew!r}")
    logits = _planted_effect(ids, sizes)
    labels = (jax.random.uniform(k_lab, (batch,)) <
              jax.nn.sigmoid(logits)).astype(jnp.float32)
    return {"ids": ids, "labels": labels}
