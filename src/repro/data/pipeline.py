"""Sharded, deterministic, restartable input pipeline.

Design goals for the 1000+-node posture (DESIGN.md §5):

* **Step-indexed determinism** — ``loader.batch(step)`` is a pure function;
  restart from checkpoint step S replays exactly the stream from S with no
  pipeline state to save.
* **Device placement** — batches are created already laid out with the
  global batch dimension sharded over the data (and pod) mesh axes, so no
  host-side reshard happens on the critical path.
* **Straggler mitigation** — synthetic generation is compute-trivial and
  happens on-device under jit; there is no host I/O to straggle on. For real
  file-backed sources, the same interface would be backed by a deadline +
  skip-and-log policy (documented, not needed for synthetic data).
* **Prefetch** — ``iter_prefetch`` keeps ``depth`` batches in flight using
  jax's async dispatch (no threads needed: dispatch is non-blocking).
"""

from __future__ import annotations

import collections
from typing import Iterator

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .synthetic import DatasetSchema, synthetic_batch

__all__ = ["CTRLoader"]


class CTRLoader:
    """Deterministic synthetic CTR stream, sharded over the mesh."""

    def __init__(self, schema: DatasetSchema, batch: int,
                 mesh: Mesh | None = None,
                 batch_axes: tuple[str, ...] = ("data",)):
        self.schema = schema
        self.batch = batch
        self.mesh = mesh
        self.batch_axes = batch_axes
        if mesh is not None:
            baxis = batch_axes if len(batch_axes) > 1 else batch_axes[0]
            self._shardings = {
                "ids": NamedSharding(mesh, P(baxis, None)),
                "labels": NamedSharding(mesh, P(baxis)),
            }
        else:
            self._shardings = None
        self._gen = jax.jit(
            lambda step: synthetic_batch(schema, step, batch),
            static_argnums=())

    def __call__(self, step: int) -> dict[str, jax.Array]:
        out = synthetic_batch(self.schema, step, self.batch)
        if self._shardings is not None:
            out = {k: jax.device_put(v, self._shardings[k])
                   for k, v in out.items()}
        return out

    def iter_prefetch(self, start_step: int, n_steps: int,
                      depth: int = 2) -> Iterator[tuple[int, dict]]:
        """Yield (step, batch) keeping ``depth`` batches dispatched ahead."""
        queue: collections.deque = collections.deque()
        for step in range(start_step, start_step + min(depth, n_steps)):
            queue.append((step, self(step)))
        for step in range(start_step + depth, start_step + n_steps):
            yield queue.popleft()
            queue.append((step, self(step)))
        while queue:
            yield queue.popleft()
