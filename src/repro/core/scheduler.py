"""Breadth-first stream scheduling (paper Algorithm 2, contribution C4).

GPU original: put the explicit/implicit interaction branches on two CUDA
streams and *interleave* operator launches breadth-first, longer branch
first, so both branches start executing as early as possible.

TPU adaptation (DESIGN.md §2): there are no user-visible streams — XLA's
static scheduler decides concurrency from the HLO dependence graph. The
schedule produced here is used as the **trace order** by the executor, which
(a) reproduces Alg. 2 exactly as a queue-construction algorithm, (b) gives
XLA an interference-free interleaved program, and (c) is inspectable: tests
assert the queue is a valid topological order and benchmarks compare
breadth-first vs depth-first orders end to end.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from .opgraph import FusedOp, Op, OpGraph

__all__ = ["LogicalStream", "breadth_first_schedule", "depth_first_schedule",
           "Schedule"]


@dataclasses.dataclass
class LogicalStream:
    """TPU stand-in for a CUDA stream: an ordered launch lane.

    Ops inside one stream are sequential; ops in different streams carry no
    ordering constraint beyond data dependence (= what multi-stream gives
    the GPU, and what the dependence graph gives XLA).
    """
    name: str
    ops: list[str] = dataclasses.field(default_factory=list)

    def add(self, ops: Sequence[str]) -> None:
        self.ops.extend(ops)


@dataclasses.dataclass
class Schedule:
    streams: dict[str, LogicalStream]
    queue: list[str]                  # launch order (the paper's Q)
    policy: str

    def stream_of(self, op_name: str) -> str:
        for s in self.streams.values():
            if op_name in s.ops:
                return s.name
        raise KeyError(op_name)


def breadth_first_schedule(explicit: Sequence[Op | FusedOp],
                           implicit: Sequence[Op | FusedOp], *,
                           first: str = "longer") -> Schedule:
    """Literal transcription of Algorithm 2.

    Args:
        explicit: ops of the explicit interaction module (in branch order).
        implicit: ops of the implicit interaction module.
        first: which branch heads the queue — ``"longer"`` (Alg.-2 default:
            "the module that has more operators launches first … it can
            help hide the startup costs"; ties go to explicit),
            ``"shorter"`` (the flipped ablation), or ``"explicit"`` /
            ``"implicit"`` (the §V-H startup-sequence ablations,
            deterministic regardless of branch lengths — including
            equal-length branches).

    Returns:
        Schedule with S_explicit / S_implicit streams and interleaved Q.
    """
    ops_explicit = [op.name for op in explicit]          # line 1
    ops_implicit = [op.name for op in implicit]          # line 2
    n_explicit = len(ops_explicit)                       # line 3
    n_implicit = len(ops_implicit)                       # line 4
    s_explicit = LogicalStream("S_explicit")             # line 5
    s_implicit = LogicalStream("S_implicit")             # line 6
    s_explicit.add(ops_explicit)                         # line 7
    s_implicit.add(ops_implicit)                         # line 8
    queue: list[str] = []
    if first == "explicit":
        head, tail_b = ops_explicit, ops_implicit
    elif first == "implicit":
        head, tail_b = ops_implicit, ops_explicit
    elif first in ("longer", "shorter"):
        # line 9: the module with more operators launches first
        head, tail_b = ((ops_implicit, ops_explicit)
                        if n_implicit > n_explicit
                        else (ops_explicit, ops_implicit))
        if first == "shorter":
            head, tail_b = tail_b, head
    else:
        raise ValueError(f"first must be 'longer', 'shorter', 'explicit' "
                         f"or 'implicit', got {first!r}")
    for i in range(min(len(head), len(tail_b))):         # lines 9–13 / 18–22
        queue.append(head[i])
        queue.append(tail_b[i])
    tail = head if len(head) >= len(tail_b) else tail_b
    for j in range(min(len(head), len(tail_b)), len(tail)):  # 14–16 / 23–25
        queue.append(tail[j])
    return Schedule(streams={"S_explicit": s_explicit,
                             "S_implicit": s_implicit},
                    queue=queue, policy="breadth_first")


def depth_first_schedule(explicit: Sequence[Op | FusedOp],
                         implicit: Sequence[Op | FusedOp],
                         explicit_first: bool = True) -> Schedule:
    """The framework-default strawman: drain one stream, then the other."""
    ops_explicit = [op.name for op in explicit]
    ops_implicit = [op.name for op in implicit]
    s_explicit = LogicalStream("S_explicit", list(ops_explicit))
    s_implicit = LogicalStream("S_implicit", list(ops_implicit))
    queue = (ops_explicit + ops_implicit if explicit_first
             else ops_implicit + ops_explicit)
    return Schedule(streams={"S_explicit": s_explicit,
                             "S_implicit": s_implicit},
                    queue=queue, policy="depth_first")


def full_order(graph: OpGraph, schedule: Schedule) -> list[str]:
    """Embed the two-branch queue into the whole-graph execution order:
    embedding ops first (both branches consume the embedded features), then
    the interleaved queue, then head ops."""
    pre = [op.name for op in graph.ops if op.module == "embedding"]
    post = [op.name for op in graph.ops
            if op.module not in ("embedding", "explicit", "implicit")]
    order = pre + schedule.queue + post
    if not graph.is_valid_order(order):
        raise ValueError(
            f"{schedule.policy} queue is not a valid topological order — "
            "branch ops must be emitted in intra-branch dependence order")
    return order
