"""DualParallelExecutor — contribution C1, tying C2–C5 together.

A CTR model exposes its forward pass as an ``OpGraph`` with four modules:
``embedding`` → (``explicit`` ∥ ``implicit``) → ``head``. The executor turns
that graph into a runnable step function at one of four optimization levels,
mirroring the paper's Fig.-8 breakdown exactly:

  level "naive"      per-field serial embedding, op-by-op eager dispatch,
                     depth-first order             (PyTorch-A analogue)
  level "fused_emb"  Alg.-1 fused mega-table lookup, rest eager
                                                    (DPIFrame-A)
  level "fused_all"  + non-GEMM subgraph fusion (C5), fused groups each
                     dispatched as one unit         (DPIFrame-B)
  level "dual"       + breadth-first interleaved branch schedule (C4) and
                     whole-graph jit so XLA's static scheduler can overlap
                     the two branches               (DPIFrame-C)

"Eager" here means each op is dispatched as its own jit-compiled call with
its own host→device round trip — the JAX reflection of per-kernel launch
overhead that the paper attributes to PyTorch. "dual" traces the whole graph
(in breadth-first order) into ONE XLA program.

Accuracy invariance (paper Table I): every level computes the identical
function — asserted in tests to float exactness on same-backend dispatch.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from .opgraph import OpGraph, fuse_non_gemm, op_outputs
from .scheduler import (breadth_first_schedule, depth_first_schedule,
                        full_order)

__all__ = ["DualParallelExecutor", "ExecutorStats", "LEVELS", "BRANCH_ORDERS"]

LEVELS = ("naive", "fused_emb", "fused_all", "dual")
BRANCH_ORDERS = ("longer_first", "explicit_first", "implicit_first")


@dataclasses.dataclass
class ExecutorStats:
    n_ops_before: int
    n_ops_after: int
    n_fused_groups: int
    kernels_used: tuple[str, ...]
    schedule_policy: str
    queue: tuple[str, ...]
    # identity of the embedding tier the plan was compiled against
    # ("dense(rows=...,d=...)" / "cached(C=...,rows=...,d=...)"), stamped by
    # compile_plan; live hit-rate counters are on EngineStats, not here
    embedding_store: str = "none"
    # dense-branch compute dtype the graph was emitted with, plus the
    # structural quantized-matmul counters emit_mlp_ops stamps in
    # OpGraph.meta (weight bytes count the int8 payload + per-channel
    # scales; "saved" is vs the 4 B/element fp32 matrix)
    compute_dtype: str = "fp32"
    mlp_quant_matmuls: int = 0
    mlp_quant_weight_bytes: int = 0
    mlp_quant_weight_bytes_saved: int = 0


class DualParallelExecutor:
    """Builds and runs a dual-parallel inference step from a model graph.

    Args:
        graph_builder: callable ``(params, level) -> OpGraph``. Models build
            the graph differently per level only for the *embedding* module
            (serial vs fused lookup); all other ops are identical — fusion
            and scheduling are applied here, not inside the model.
        level: one of LEVELS.
        branch_order: "longer_first" (paper default), "explicit_first",
            "implicit_first" (§V-H startup-sequence ablation).
    """

    def __init__(self, graph_builder: Callable[..., OpGraph], *,
                 level: str = "dual", branch_order: str = "longer_first"):
        if level not in LEVELS:
            raise ValueError(f"level must be one of {LEVELS}, got {level!r}")
        if branch_order not in BRANCH_ORDERS:
            raise ValueError(f"branch_order must be one of {BRANCH_ORDERS}, "
                             f"got {branch_order!r}")
        self.graph_builder = graph_builder
        self.level = level
        self.branch_order = branch_order
        self._stats: ExecutorStats | None = None

    # -- graph preparation ---------------------------------------------------
    def prepare(self, params: Any) -> tuple[OpGraph, list[str]]:
        graph = self.graph_builder(params, self.level)
        n_before = graph.n_kernels()
        if self.level in ("fused_all", "dual"):
            graph = fuse_non_gemm(graph)
        explicit = graph.by_module("explicit")
        implicit = graph.by_module("implicit")
        if self.level == "dual":
            # "explicit_first"/"implicit_first" pin the head branch
            # deterministically (equal-length branches included); only
            # "longer_first" lets Alg. 2 pick by branch length.
            first = {"longer_first": "longer",
                     "explicit_first": "explicit",
                     "implicit_first": "implicit"}[self.branch_order]
            sched = breadth_first_schedule(explicit, implicit, first=first)
        else:
            sched = depth_first_schedule(explicit, implicit)
        order = full_order(graph, sched)
        fused_groups = [op for op in graph.ops if hasattr(op, "members")]
        self._stats = ExecutorStats(
            n_ops_before=n_before,
            n_ops_after=graph.n_kernels(),
            n_fused_groups=len(fused_groups),
            kernels_used=tuple(op.kernel for op in fused_groups
                               if getattr(op, "kernel", None)),
            schedule_policy=sched.policy,
            queue=tuple(sched.queue),
            compute_dtype=graph.meta.get("compute_dtype", "fp32"),
            mlp_quant_matmuls=graph.meta.get("mlp_quant_matmuls", 0),
            mlp_quant_weight_bytes=graph.meta.get(
                "mlp_quant_weight_bytes", 0),
            mlp_quant_weight_bytes_saved=graph.meta.get(
                "mlp_quant_weight_bytes_saved", 0),
        )
        return graph, order

    @property
    def stats(self) -> ExecutorStats:
        if self._stats is None:
            raise RuntimeError("call build() first")
        return self._stats

    # -- runnable step ---------------------------------------------------------
    def build(self, params: Any) -> Callable[[dict[str, Any]], Any]:
        """Returns ``step(inputs_env) -> output`` at the configured level."""
        graph, order = self.prepare(params)
        return self.make_step(graph, order)

    def make_step(self, graph: OpGraph, order: list[str], *,
                  donate: bool = False) -> Callable[..., Any]:
        """Turn a prepared (graph, order) into
        ``step(inputs_env, runtime_env=None) -> output``.

        ``inputs_env`` carries the per-request values (``ids``);
        ``runtime_env`` carries runtime store tensors (a refreshable
        embedding tier's cache/backing/index map — see
        ``EmbeddingStore.runtime_keys``) that change across refreshes but
        never per request. They are separate arguments so ``donate`` can
        consume request buffers without ever donating the published store
        tensors. Split from :meth:`build` so ``repro.core.plan.
        compile_plan`` can AOT-lower the jit without re-preparing the
        graph (``step.lower`` is exposed at level "dual").
        """
        ops_in_order = [graph.op(n) for n in order]
        out_edge = ops_in_order[-1].output

        if self.level == "dual":
            # one traced program, breadth-first trace order
            def whole(env, runtime_env):
                e = graph.execute({**env, **runtime_env}, order)
                return e[out_edge]
            jitted_whole = jax.jit(whole,
                                   donate_argnums=(0,) if donate else ())

            def step(env, runtime_env=None):
                return jitted_whole(env, runtime_env or {})
            step.lower = jitted_whole.lower
            return step

        # eager op-by-op dispatch: each op is its own jit call (its own
        # device dispatch), mirroring per-kernel launch overhead
        jitted = [jax.jit(op.fn) for op in ops_in_order]

        def eager(env, runtime_env=None):
            env = {**env, **(runtime_env or {})}
            for op, jfn in zip(ops_in_order, jitted):
                res = jfn(*[env[e] for e in op.inputs])
                outs = op_outputs(op)
                if len(outs) == 1:
                    env[outs[0]] = res
                else:
                    for name, val in zip(outs, res):
                        env[name] = val
                jax.block_until_ready(res)
            return env[out_edge]
        return eager
