"""repro.core — DPIFrame's contribution as composable JAX modules.

  opgraph.py          C5: operator DAG + non-GEMM fusion pass
  scheduler.py        C4: breadth-first stream scheduling (Alg. 2)
  dual_parallel.py    C1: the dual-parallel executor (Fig.-8 levels)
  plan.py             compile_plan → InferencePlan, the compiled artifact
                      consumed by repro.serving.InferenceEngine

The C2 embedding path lives in ``repro.embedding`` (re-exported here for
convenience).
"""

from .dual_parallel import (BRANCH_ORDERS, LEVELS, DualParallelExecutor,
                            ExecutorStats)
from .plan import (COMPUTE_DTYPES, InferencePlan, PlanKey, compile_plan,
                   place_params, plan_key_for)
from repro.embedding import (CachedStore, DenseStore, EmbeddingStore,
                             FusedEmbeddingCollection, FusedEmbeddingSpec,
                             HostBackedStore, StoreStats,
                             sharded_vocab_lookup)
from .opgraph import Op, FusedOp, OpGraph, fuse_non_gemm, register_fused_kernel
from .scheduler import (breadth_first_schedule, depth_first_schedule,
                        full_order)

__all__ = [
    "LEVELS",
    "BRANCH_ORDERS",
    "DualParallelExecutor",
    "ExecutorStats",
    "COMPUTE_DTYPES",
    "InferencePlan",
    "PlanKey",
    "compile_plan",
    "place_params",
    "plan_key_for",
    "FusedEmbeddingCollection",
    "FusedEmbeddingSpec",
    "EmbeddingStore",
    "DenseStore",
    "CachedStore",
    "HostBackedStore",
    "StoreStats",
    "sharded_vocab_lookup",
    "Op",
    "FusedOp",
    "OpGraph",
    "fuse_non_gemm",
    "register_fused_kernel",
    "breadth_first_schedule",
    "depth_first_schedule",
    "full_order",
]
