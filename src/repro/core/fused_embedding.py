"""DEPRECATED compatibility shim — use ``repro.embedding`` instead.

The mega-table spec, the store tier (``DenseStore``/``CachedStore``), and
``FusedEmbeddingCollection`` moved into the :mod:`repro.embedding`
subsystem when the cache-aware parameter-server refactor landed. This
module keeps the historical import path ``repro.core.fused_embedding``
working (with a ``DeprecationWarning``); nothing in-repo imports it
anymore — ``repro.core`` re-exports straight from ``repro.embedding``.
"""

import warnings

warnings.warn(
    "repro.core.fused_embedding is deprecated; import from repro.embedding "
    "instead (same names: FusedEmbeddingSpec, FusedEmbeddingCollection, "
    "EmbeddingStore, DenseStore, CachedStore, StoreStats, "
    "sharded_vocab_lookup).", DeprecationWarning, stacklevel=2)

from repro.embedding import (CachedStore, DenseStore, EmbeddingStore,
                             FusedEmbeddingCollection, FusedEmbeddingSpec,
                             StoreStats, sharded_vocab_lookup)

__all__ = ["FusedEmbeddingSpec", "FusedEmbeddingCollection",
           "EmbeddingStore", "DenseStore", "CachedStore", "StoreStats",
           "sharded_vocab_lookup"]
