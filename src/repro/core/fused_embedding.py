"""Compatibility shim — the embedding path now lives in ``repro.embedding``.

The mega-table spec, the store tier (``DenseStore``/``CachedStore``), and
``FusedEmbeddingCollection`` moved into the :mod:`repro.embedding`
subsystem when the cache-aware parameter-server refactor landed. This
module keeps the historical import path
(``repro.core.fused_embedding`` / ``repro.core``) working.
"""

from repro.embedding import (CachedStore, DenseStore, EmbeddingStore,
                             FusedEmbeddingCollection, FusedEmbeddingSpec,
                             StoreStats, sharded_vocab_lookup)

__all__ = ["FusedEmbeddingSpec", "FusedEmbeddingCollection",
           "EmbeddingStore", "DenseStore", "CachedStore", "StoreStats",
           "sharded_vocab_lookup"]
