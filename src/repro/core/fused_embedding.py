"""FusedEmbeddingCollection — the mega-table realization of paper Alg. 1.

All k per-field embedding tables are concatenated row-wise into ONE
``mega_table`` parameter; per-field ids become global rows via static
offsets. One gather (Pallas on TPU / single XLA gather on CPU) replaces k
serial lookups — contribution C2, with C3's output-first allocation inside
the kernel.

Distribution: the mega-table is *row-sharded* over the ``model`` mesh axis
(vocab-parallel). ``apply_sharded`` performs the masked-local-gather + psum
pattern under ``shard_map`` — the multi-chip generalization of Alg. 1; the
same helper serves LM vocab embeddings (a 1-table degenerate case).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.kernels import ops as kops

__all__ = ["FusedEmbeddingSpec", "FusedEmbeddingCollection",
           "sharded_vocab_lookup"]


@dataclasses.dataclass(frozen=True)
class FusedEmbeddingSpec:
    """Static description of a CTR embedding module.

    Attributes:
        field_sizes: number of features n_i per field (len = k).
        dim:         shared embedding dimension d.
        multi_hot:   max ids per field (1 = one-hot fields).
        dtype:       parameter dtype.
        pad_rows_to: pad the mega-table height to a multiple (sharding).
    """
    field_sizes: tuple[int, ...]
    dim: int
    multi_hot: int = 1
    dtype: str = "float32"
    pad_rows_to: int = 1

    @property
    def k(self) -> int:
        return len(self.field_sizes)

    @property
    def rows(self) -> int:
        """Mega-table height: all fields + 1 zero row (multi-hot masking),
        padded up for even sharding."""
        n = int(sum(self.field_sizes)) + 1
        pad = self.pad_rows_to
        return ((n + pad - 1) // pad) * pad

    @property
    def offsets(self) -> np.ndarray:
        return np.concatenate(
            [[0], np.cumsum(self.field_sizes)[:-1]]).astype(np.int32)

    @property
    def zero_row(self) -> int:
        return int(sum(self.field_sizes))

    @property
    def n_params(self) -> int:
        return self.rows * self.dim


class FusedEmbeddingCollection:
    """Parameter container + lookup front-end for the fused mega-table."""

    def __init__(self, spec: FusedEmbeddingSpec):
        self.spec = spec
        self._offsets = jnp.asarray(spec.offsets)

    # -- params ------------------------------------------------------------
    def init(self, key: jax.Array) -> dict:
        spec = self.spec
        scale = 1.0 / np.sqrt(spec.dim)
        table = jax.random.normal(
            key, (spec.rows, spec.dim), dtype=jnp.dtype(spec.dtype)) * scale
        # zero row (and padding rows) must stay zero for multi-hot masking
        table = table.at[spec.zero_row:].set(0.0)
        return {"mega_table": table}

    def partition_spec(self, model_axis: str | None = "model") -> dict:
        """Row-sharded (vocab-parallel) placement of the mega-table."""
        return {"mega_table": P(model_axis, None)}

    # -- single-device / replicated lookup ----------------------------------
    def apply(self, params: dict, ids: jax.Array, *,
              strategy: str = "auto", interpret: bool | None = None
              ) -> jax.Array:
        """ids (b, k) -> (b, k*d)."""
        return kops.multi_table_lookup(
            ids, params["mega_table"], self._offsets,
            strategy=strategy, interpret=interpret)

    def apply_multihot(self, params: dict, ids: jax.Array, mask: jax.Array,
                       *, strategy: str = "auto",
                       interpret: bool | None = None) -> jax.Array:
        """ids/mask (b, k, h) -> (b, k*d) sum-pooled."""
        return kops.multi_table_lookup_multihot(
            ids, mask, params["mega_table"], self._offsets,
            strategy=strategy, interpret=interpret)

    def apply_serial(self, params: dict, ids: jax.Array) -> jax.Array:
        """Baseline: k separate gathers + concat (PyTorch-A analogue)."""
        return kops.multi_table_lookup(
            ids, params["mega_table"], self._offsets, strategy="serial")

    # -- distributed lookup --------------------------------------------------
    def apply_sharded(self, params: dict, ids: jax.Array, mesh: jax.sharding.Mesh,
                      *, model_axis: str = "model",
                      batch_axes: tuple[str, ...] = ("data",)) -> jax.Array:
        """Vocab-parallel fused lookup over a row-sharded mega-table.

        Each shard gathers locally (out-of-range rows masked to 0) and the
        partial results are summed over the model axis — one psum replaces
        k independent lookups' worth of gather traffic.
        """
        b, k = ids.shape
        d = self.spec.dim
        global_rows = (ids.astype(jnp.int32) + self._offsets[None, :])

        def _local(rows, table):
            axis_idx = jax.lax.axis_index(model_axis)
            shard_rows = table.shape[0]
            lo = axis_idx * shard_rows
            local = rows - lo
            valid = (local >= 0) & (local < shard_rows)
            safe = jnp.where(valid, local, 0)
            vals = jnp.take(table, safe.reshape(-1), axis=0)
            vals = vals.reshape(*rows.shape, d)
            vals = jnp.where(valid[..., None], vals, 0)
            return jax.lax.psum(vals, axis_name=model_axis)

        baxis = batch_axes if len(batch_axes) > 1 else batch_axes[0]
        fn = shard_map(
            _local, mesh=mesh,
            in_specs=(P(baxis, None), P(model_axis, None)),
            out_specs=P(baxis, None, None),
            check_vma=False)
        out = fn(global_rows, params["mega_table"])
        return out.reshape(b, k * d)


def sharded_vocab_lookup(table: jax.Array, ids: jax.Array, *,
                         model_axis: str = "model") -> jax.Array:
    """shard_map-interior vocab-parallel lookup (LM embedding reuse).

    Call *inside* an existing shard_map / with sharded ``table`` rows:
    masked local gather + psum over ``model_axis``.

    Args:
        table: (rows_per_shard, d) local shard of the embedding table.
        ids:   (...,) global token ids.

    Returns:
        (..., d) embeddings, replicated over the model axis.
    """
    shard_rows = table.shape[0]
    axis_idx = jax.lax.axis_index(model_axis)
    lo = axis_idx * shard_rows
    local = ids.astype(jnp.int32) - lo
    valid = (local >= 0) & (local < shard_rows)
    safe = jnp.where(valid, local, 0)
    vals = jnp.take(table, safe.reshape(-1), axis=0)
    vals = vals.reshape(*ids.shape, table.shape[1])
    vals = jnp.where(valid[..., None], vals, 0)
    return jax.lax.psum(vals, axis_name=model_axis)
