"""InferencePlan — the immutable compiled artifact of the serving stack.

The repo's execution API has three explicit layers (HugeCTR's inference
parameter server and PCDF's parallel-computing serving framework follow the
same decomposition):

  1. **compile** — :func:`compile_plan` turns (model, params, level,
     batch shape) into an :class:`InferencePlan` once: the fused ``OpGraph``,
     the breadth-first schedule, the ``ExecutorStats`` bookkeeping, and a
     runnable step. At level ``"dual"`` the step is AOT-lowered and
     compiled via ``jax.jit(...).lower(...).compile()`` so the first served
     request never pays trace/compile time; the other Fig.-8 levels keep
     their deliberate op-by-op dispatch but have every per-op jit warmed.
  2. **plan** — the ``InferencePlan`` is immutable and batch-shape-specific;
     it can be cached, shipped across engines, and called directly
     (``plan(ids) -> logits``, ``plan.predict(ids) -> scores``). A
     refreshable embedding store's tensors are *runtime inputs* of the
     step (``runtime_inputs``), not baked constants, so plans survive
     cache refreshes unchanged.
  3. **engine** — ``repro.serving.engine.InferenceEngine`` owns a cache of
     plans keyed by ``(model, level, batch_bucket)`` plus a pluggable
     batching policy (``repro.serving.batching``).

With ``mesh=`` the plan is a real multi-chip serving artifact: the
embedding mega-tables are placed row-sharded (vocab-parallel, the
``FusedEmbeddingCollection.partition_spec`` placement) over the mesh's
model axis before tracing, per-call batch inputs are sharded over the data
axis, and the compiled program runs under GSPMD. The resolved placements
are recorded on the plan (``input_shardings``/``runtime_shardings``) so
the serving layers can ``device_put`` incoming batches and — critically —
so a cache refresh republishes *placed* tensors (``place_params`` /
``EmbeddingStore.place``) instead of unplaced host arrays.

``DualParallelExecutor`` remains the graph-preparation machinery underneath;
user code should not need to touch it directly anymore.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import numpy as np
import jax
import jax.numpy as jnp

from .dual_parallel import (BRANCH_ORDERS, LEVELS, DualParallelExecutor,
                            ExecutorStats)
from .opgraph import OpGraph

__all__ = ["PlanKey", "InferencePlan", "compile_plan", "plan_key_for",
           "place_params", "COMPUTE_DTYPES"]

#: dense-branch compute dtypes a plan can be compiled at: fp32 GEMMs, or
#: int8 matmuls with fused in-kernel dequant (kernels.dense_matmul_q8)
COMPUTE_DTYPES = ("fp32", "int8")


@dataclasses.dataclass(frozen=True)
class PlanKey:
    """Cache identity of a compiled plan (the engine's cache key)."""
    model: str
    level: str
    batch_size: int
    branch_order: str = "longer_first"
    sharded: bool = False
    store: str = "dense"
    compute_dtype: str = "fp32"


def _store_describe(model) -> str:
    """Embedding-store identity of a model (plan keys and stats carry it:
    two models differing only in store tiers must never share plans)."""
    coll = getattr(model, "embedding", None)
    store = getattr(coll, "store", None)
    return store.describe() if store is not None else "none"


def plan_key_for(model, level: str, batch_size: int,
                 branch_order: str = "longer_first",
                 sharded: bool = False,
                 compute_dtype: str = "fp32") -> PlanKey:
    """The single definition of plan/cache identity — used both by
    :func:`compile_plan` (stamped on the plan) and by engines keying their
    caches, so the two can never drift."""
    return PlanKey(model=getattr(model.spec, "name", type(model).__name__),
                   level=level, batch_size=int(batch_size),
                   branch_order=branch_order, sharded=sharded,
                   store=_store_describe(model),
                   compute_dtype=compute_dtype)


@dataclasses.dataclass(frozen=True)
class InferencePlan:
    """One compiled, batch-shape-specific inference artifact.

    ``step`` maps ``ids (batch_size, n_fields) int32 -> logits``; it is the
    AOT-compiled executable at level "dual" and the warmed eager chain at
    the other levels. Plans are immutable: recompile to change anything —
    with one deliberate exception: ``runtime_inputs`` names the embedding
    store tensors (a refreshable tier's cache/backing/index map) that the
    step takes as *per-call arguments* instead of baked constants. Their
    values come from the ``runtime_provider`` the plan was compiled with,
    so swapping the published tensors (a cache refresh) retargets every
    call without touching the compiled program.
    """
    key: PlanKey
    stats: ExecutorStats
    graph: OpGraph
    order: tuple[str, ...]
    step: Callable[[jax.Array], jax.Array]
    n_fields: int
    donate: bool
    compile_ms: float
    runtime_inputs: tuple[str, ...] = ()
    #: mesh the plan was compiled against (None = single device)
    mesh: jax.sharding.Mesh | None = None
    #: per-call input leaf -> NamedSharding ("ids": batch dim over the
    #: mesh's data axis, fit_spec fallback for odd batch sizes); empty
    #: without a mesh. The step device_puts incoming batches to these, and
    #: engines may pre-place batches themselves.
    input_shardings: dict = dataclasses.field(default_factory=dict)
    #: runtime-input edge -> NamedSharding (the store placement the step
    #: was lowered against: backing/mega row-sharded over model, cache +
    #: slot_of_row replicated). A mesh-aware refresh MUST republish fresh
    #: tensors placed to exactly these.
    runtime_shardings: dict = dataclasses.field(default_factory=dict)

    @property
    def level(self) -> str:
        return self.key.level

    @property
    def batch_size(self) -> int:
        return self.key.batch_size

    def __call__(self, ids: jax.Array) -> jax.Array:
        return self.step(ids)

    def predict(self, ids) -> np.ndarray:
        """Sigmoid scores for ``ids`` ((n_fields,) or (b, n_fields) with
        b ≤ batch_size); pads up to the plan's batch shape and slices the
        padding back off."""
        ids = np.asarray(ids, dtype=np.int32)
        if ids.ndim == 1:
            ids = ids[None, :]
        b = ids.shape[0]
        if b > self.batch_size:
            raise ValueError(
                f"{b} rows > plan batch_size {self.batch_size}; use an "
                "InferenceEngine (it batches) or compile a bigger plan")
        if b < self.batch_size:
            pad = np.zeros((self.batch_size - b, ids.shape[1]),
                           dtype=ids.dtype)
            ids = np.concatenate([ids, pad])
        logits = self.step(jnp.asarray(ids))
        return np.asarray(
            jax.nn.sigmoid(jnp.reshape(jnp.asarray(logits), (-1,))))[:b]


def _shard_params(params: Any, mesh: jax.sharding.Mesh, model_axis: str,
                  specs: Any = None) -> Any:
    """Place params on ``mesh`` per a PartitionSpec tree.

    ``specs`` comes from the model's ``partition_spec(params)`` — which
    delegates embedding subtrees to their store — so placement follows the
    parameter *structure*, not fragile name matching (the old
    ``"mega" in names`` heuristic broke as soon as a store renamed or
    nested its leaves). Leaves whose leading dim doesn't divide the axis
    fall back to replication; ``specs=None`` replicates everything.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    n_shards = dict(zip(mesh.axis_names, mesh.devices.shape)).get(
        model_axis, 1)
    if specs is None:
        specs = jax.tree.map(lambda _: P(), params)

    def place(leaf, spec):
        dims = tuple(spec)
        if (dims and dims[0] == model_axis
                and (getattr(leaf, "ndim", 0) == 0
                     or leaf.shape[0] % n_shards != 0)):
            spec = P()
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return jax.tree.map(place, params, specs)


def place_params(model, params: Any, mesh: jax.sharding.Mesh,
                 model_axis: str = "model") -> Any:
    """Place a model's params on ``mesh`` per its structural
    ``partition_spec`` (embedding subtrees delegated to their store:
    backing/mega row-sharded vocab-parallel, cache tiers replicated).

    The one placement entry point shared by :func:`compile_plan` and
    ``InferenceEngine`` — an engine with a mesh places its live params
    here once at construction, so the provider feeding runtime store
    tensors into compiled steps always hands out *placed* arrays. On a
    mesh without the model axis (e.g. ``data``-only), tables replicate.
    """
    axis = model_axis if model_axis in mesh.axis_names else None
    specs = (model.partition_spec(params, axis)
             if hasattr(model, "partition_spec") else None)
    return _shard_params(params, mesh, axis, specs)


def compile_plan(model, params: Any, level: str = "dual",
                 batch_size: int = 256, *,
                 mesh: jax.sharding.Mesh | None = None,
                 donate: bool = False,
                 branch_order: str = "longer_first",
                 model_axis: str = "model",
                 runtime_provider: Callable[[], dict] | None = None,
                 compute_dtype: str = "fp32") -> InferencePlan:
    """Compile one (model, level, batch shape) into an InferencePlan.

    Args:
        model: a ``CTRModel`` (anything with ``spec.k`` and
            ``build_graph(params, level)``).
        params: the model's parameter pytree.
        level: one of ``repro.core.LEVELS`` (the Fig.-8 ladder).
        batch_size: the fixed batch shape this plan serves.
        mesh: optional device mesh; mega-tables are row-sharded over its
            ``model_axis`` before tracing (vocab-parallel placement) and
            per-call batch inputs are sharded over its data axis
            (``distributed.sharding.batch_specs`` with a ``fit_spec``
            replication fallback when the batch size doesn't divide the
            axis). The resolved placements are recorded on the plan
            (``input_shardings``/``runtime_shardings``) so engines can
            ``device_put`` incoming batches and refresh swaps to them.
        donate: donate the input buffer to the compiled step (XLA may
            reuse it; callers must treat submitted arrays as consumed).
            Only meaningful at level ``"dual"`` — the eager levels dispatch
            op-by-op and ignore it. Runtime store tensors are never
            donated (they are shared across calls and plans).
        branch_order: breadth-first head-branch policy (§V-H ablations).
        runtime_provider: zero-arg callable returning the current runtime
            store tensors (edge name -> array, the plan's
            ``runtime_inputs``), consulted on *every* step call. Default:
            bind the tensors in ``params`` at compile time — equivalent to
            the old baked-constant behavior. ``InferenceEngine`` passes a
            provider reading its live params so a ``refresh_cache()``
            tensor swap retargets every cached plan with zero recompiles.
        compute_dtype: ``"fp32"`` (default) or ``"int8"`` — quantize every
            dense-branch matmul: weights per output channel *once here at
            compile* (baked int8 constants — MLP weights are not runtime
            inputs, so refresh stays recompile-free), activations per row
            dynamically inside the fused ``dense_matmul_q8`` kernel. Part
            of the plan's cache identity, so quantized and fp32 plans
            coexist in one engine cache.
    """
    if level not in LEVELS:
        raise ValueError(f"level must be one of {LEVELS}, got {level!r}")
    if branch_order not in BRANCH_ORDERS:
        raise ValueError(f"branch_order must be one of {BRANCH_ORDERS}, "
                         f"got {branch_order!r}")
    if compute_dtype not in COMPUTE_DTYPES:
        raise ValueError(f"compute_dtype must be one of {COMPUTE_DTYPES}, "
                         f"got {compute_dtype!r}")
    if mesh is not None:
        params = place_params(model, params, mesh, model_axis)

    builder = model.build_graph
    if compute_dtype != "fp32":
        def builder(p, lvl, _build=model.build_graph):
            return _build(p, lvl, compute_dtype=compute_dtype)
    executor = DualParallelExecutor(builder, level=level,
                                    branch_order=branch_order)
    t0 = time.perf_counter()
    graph, order = executor.prepare(params)
    step_env = executor.make_step(graph, order, donate=donate)
    n_fields = model.spec.k

    # runtime store tensors (refreshable tiers only): extra step inputs,
    # re-read from the provider each call instead of baked into the program
    runtime = (model.store_runtime_env(params)
               if hasattr(model, "store_runtime_env") else {})
    provider = runtime_provider if runtime_provider is not None \
        else (lambda: runtime)

    # resolved shardings (the multi-chip serving contract, recorded on the
    # plan): per-call inputs batch-sharded over the mesh's data axis with
    # fit_spec fallback for batch sizes the axis doesn't divide; runtime
    # store tensors carry the placement place_params gave them (backing/
    # mega row-sharded over model, cache + slot_of_row replicated)
    in_shardings: dict = {}
    rt_shardings: dict = {}
    if mesh is not None:
        from repro.distributed.sharding import input_shardings
        in_shardings = input_shardings(
            mesh, {"ids": jax.ShapeDtypeStruct((batch_size, n_fields),
                                               jnp.int32)})
        rt_shardings = {k: v.sharding for k, v in runtime.items()}

    def bind_inputs(ids: jax.Array) -> dict:
        if in_shardings:
            ids = jax.device_put(ids, in_shardings["ids"])
        return {"ids": ids}

    def bind_runtime() -> dict:
        env = provider()
        if rt_shardings:
            # no-op for tensors already placed (the refresh path places
            # before publishing); a safety net for callers that swap in
            # raw host arrays
            env = {k: jax.device_put(v, rt_shardings[k])
                   for k, v in env.items()}
        return env

    if level == "dual":
        # AOT: lower + compile the whole-graph program now, not on first
        # use — with the resolved input/runtime shardings baked into the
        # lowered avals so GSPMD partitions the program for the mesh
        spec = {"ids": jax.ShapeDtypeStruct(
            (batch_size, n_fields), jnp.int32,
            sharding=in_shardings.get("ids"))}
        rt_spec = {k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                           sharding=rt_shardings.get(k))
                   for k, v in runtime.items()}
        compiled = step_env.lower(spec, rt_spec).compile()

        def step(ids: jax.Array) -> jax.Array:
            return compiled(bind_inputs(ids), bind_runtime())
    else:
        # eager levels dispatch op-by-op on purpose; warm every per-op jit
        # so serving latency never includes compiles
        def step(ids: jax.Array) -> jax.Array:
            return step_env(bind_inputs(ids), bind_runtime())
        jax.block_until_ready(
            step(jnp.zeros((batch_size, n_fields), dtype=jnp.int32)))
    compile_ms = (time.perf_counter() - t0) * 1e3

    key = plan_key_for(model, level, batch_size, branch_order,
                       sharded=mesh is not None,
                       compute_dtype=compute_dtype)
    stats = executor.stats
    stats.embedding_store = _store_describe(model)
    stats.compute_dtype = compute_dtype
    return InferencePlan(key=key, stats=stats, graph=graph,
                         order=tuple(order), step=step, n_fields=n_fields,
                         donate=donate, compile_ms=compile_ms,
                         runtime_inputs=tuple(sorted(runtime)),
                         mesh=mesh, input_shardings=in_shardings,
                         runtime_shardings=rt_shardings)
