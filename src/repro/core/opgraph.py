"""Operator DAG + non-GEMM fusion pass (paper contribution C5).

DPIFrame "represents the model forward propagation by constructing a
directed acyclic graph, in which nodes are operators and edges are tensors.
Starting from the root node, we traverse the graph to mark all non-GEMM
nodes connected by edges … within a subgraph, we fuse the operators into a
new operator."  This module is that pass, backend-agnostically:

* ``Op``        one operator node (fn + named input/output edges).
* ``OpGraph``   the DAG; validates SSA form, checks topological orders.
* ``fuse_non_gemm``  merges every maximal run of same-module non-GEMM ops
  into a single ``FusedOp`` (multi-output when several of its values are
  consumed downstream); if all members carry the same ``fused_hint`` and the
  group is single-output, the registered Pallas kernel replaces the composed
  body. Kernel dispatch is exact-math, so fusion never changes results —
  the paper's Table-I bit-parity property.

Execution engines (how a schedule is *run*) live in dual_parallel.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

__all__ = ["Op", "FusedOp", "OpGraph", "register_fused_kernel",
           "fuse_non_gemm", "op_outputs"]


@dataclasses.dataclass(frozen=True)
class Op:
    """One operator node.

    Attributes:
        name:       unique node id.
        fn:         callable ``(*input_values) -> value`` (may close over
                    parameters — edges carry activations only).
        inputs:     names of the value edges consumed.
        output:     name of the produced value edge.
        is_gemm:    True for MXU-bound matmuls — never fused (the paper
                    fuses only non-GEMM ops).
        module:     model module tag ("embedding", "explicit", "implicit",
                    "head"); fusion never crosses module boundaries and
                    scheduling interleaves by module.
        fused_hint: optional pattern tag; a homogeneous fused group with a
                    registered hint dispatches to its Pallas kernel.
    """
    name: str
    fn: Callable[..., Any]
    inputs: tuple[str, ...]
    output: str
    is_gemm: bool = False
    module: str = ""
    fused_hint: str | None = None


@dataclasses.dataclass(frozen=True)
class FusedOp:
    """A fused group of non-GEMM ops executing as one dispatch unit."""
    name: str
    fn: Callable[..., Any]           # (*external_inputs) -> tuple(outputs)
    inputs: tuple[str, ...]          # external value edges
    outputs: tuple[str, ...]         # exposed value edges (usually 1)
    members: tuple[str, ...]         # names of the original ops
    module: str = ""
    kernel: str | None = None        # registered kernel used, if any
    is_gemm: bool = False

    @property
    def output(self) -> str:
        return self.outputs[-1]


def op_outputs(op: Op | FusedOp) -> tuple[str, ...]:
    return op.outputs if isinstance(op, FusedOp) else (op.output,)


# pattern registry: hint -> kernel with the same signature as the composed
# single-output subgraph.  Populated by repro.models.ctr at import time.
_FUSED_KERNELS: dict[str, Callable[..., Any]] = {}


def register_fused_kernel(hint: str, fn: Callable[..., Any]) -> None:
    _FUSED_KERNELS[hint] = fn


class OpGraph:
    """A small SSA-form operator DAG (ops added in topological order)."""

    def __init__(self, graph_inputs: Sequence[str]):
        self.graph_inputs = tuple(graph_inputs)
        self.ops: list[Op | FusedOp] = []
        self._producers: dict[str, str] = {}   # value edge -> op name
        # free-form structural annotations the emitters stamp at build time
        # (e.g. emit_mlp_ops' quantized-compute counters); carried through
        # fusion and surfaced in ExecutorStats — never read by execution
        self.meta: dict[str, Any] = {}

    # -- construction ------------------------------------------------------
    def add_input(self, name: str) -> None:
        """Declare an extra graph input edge (e.g. a runtime store tensor
        a refreshable embedding tier feeds per call instead of baking)."""
        if name in self._producers:
            raise ValueError(f"value {name!r} already produced by "
                             f"{self._producers[name]!r}")
        if name not in self.graph_inputs:
            self.graph_inputs = self.graph_inputs + (name,)

    def add(self, op: Op | FusedOp) -> None:
        for out in op_outputs(op):
            if out in self._producers:
                raise ValueError(f"value {out!r} already produced by "
                                 f"{self._producers[out]!r}")
        for edge in op.inputs:
            if edge not in self._producers and edge not in self.graph_inputs:
                raise ValueError(f"op {op.name!r} consumes undefined value "
                                 f"{edge!r} (ops must be added in topo order)")
        for out in op_outputs(op):
            self._producers[out] = op.name
        self.ops.append(op)

    # -- queries -----------------------------------------------------------
    def by_module(self, module: str) -> list[Op | FusedOp]:
        return [op for op in self.ops if op.module == module]

    def consumers(self, edge: str) -> list[str]:
        return [op.name for op in self.ops if edge in op.inputs]

    def op(self, name: str) -> Op | FusedOp:
        for op in self.ops:
            if op.name == name:
                return op
        raise KeyError(name)

    def is_valid_order(self, order: Sequence[str]) -> bool:
        """True if ``order`` is a topological order of this graph."""
        if sorted(order) != sorted(op.name for op in self.ops):
            return False
        ready = set(self.graph_inputs)
        by_name = {op.name: op for op in self.ops}
        for name in order:
            op = by_name[name]
            if any(e not in ready for e in op.inputs):
                return False
            ready.update(op_outputs(op))
        return True

    # -- execution ---------------------------------------------------------
    def execute(self, env: dict[str, Any],
                order: Sequence[str] | None = None) -> dict[str, Any]:
        """Run ops (in graph order or an explicit schedule) over ``env``."""
        env = dict(env)
        ops = self.ops if order is None else [self.op(n) for n in order]
        for op in ops:
            res = op.fn(*[env[e] for e in op.inputs])
            if isinstance(op, FusedOp):
                if len(op.outputs) == 1:
                    env[op.outputs[0]] = res
                else:
                    for name, val in zip(op.outputs, res):
                        env[name] = val
            else:
                env[op.output] = res
        return env

    def n_kernels(self) -> int:
        """Device dispatches this graph costs (the paper's launch-overhead
        metric: strictly fewer after fusion)."""
        return len(self.ops)


def _compose(sub_ops: list[Op], external: tuple[str, ...],
             exposed: tuple[str, ...]) -> Callable[..., Any]:
    """Build one callable running a fused subgraph internally."""
    single = len(exposed) == 1

    def fused_fn(*args):
        env = dict(zip(external, args))
        for op in sub_ops:
            env[op.output] = op.fn(*[env[e] for e in op.inputs])
        if single:
            return env[exposed[0]]
        return tuple(env[e] for e in exposed)
    return fused_fn


def _emit_group(fused: OpGraph, graph: OpGraph, group: list[Op],
                group_id: int, use_kernels: bool) -> None:
    """Add one fused group (or the single op) to the output graph."""
    if len(group) == 1:
        fused.add(group[0])
        return
    group_names = {r.name for r in group}
    group_outs = {r.output for r in group}
    # exposed = consumed by any op outside the group, or never consumed
    exposed: list[str] = []
    for r in group:
        outside = [c for c in graph.consumers(r.output)
                   if c not in group_names]
        if outside or not graph.consumers(r.output):
            exposed.append(r.output)
    external_inputs: list[str] = []
    for r in group:
        for e in r.inputs:
            if e not in group_outs and e not in external_inputs:
                external_inputs.append(e)
    hints = {r.fused_hint for r in group}
    kernel_name = None
    fn = _compose(group, tuple(external_inputs), tuple(exposed))
    if use_kernels and len(hints) == 1 and len(exposed) == 1:
        hint = next(iter(hints))
        if hint is not None and hint in _FUSED_KERNELS:
            fn = _FUSED_KERNELS[hint]
            kernel_name = hint
    fused.add(FusedOp(
        name=f"fused{group_id}[" + "+".join(r.name for r in group) + "]",
        fn=fn,
        inputs=tuple(external_inputs),
        outputs=tuple(exposed),
        members=tuple(r.name for r in group),
        module=group[0].module,
        kernel=kernel_name,
    ))


def _segment_by_kernel_hint(run: list[Op], use_kernels: bool) -> list[list[Op]]:
    """Split a non-GEMM run into fusion groups.

    Contiguous ops sharing a *registered-kernel* hint become their own group
    (so the Pallas kernel can replace the composed body); everything else is
    coalesced maximally — the paper's whole-subgraph fusion.
    """
    segs: list[list[Op]] = []
    for op in run:
        backed = (use_kernels and op.fused_hint is not None
                  and op.fused_hint in _FUSED_KERNELS)
        key = op.fused_hint if backed else None
        if segs and _seg_key(segs[-1], use_kernels) == key:
            segs[-1].append(op)
        else:
            segs.append([op])
    return segs


def _seg_key(seg: list[Op], use_kernels: bool):
    op = seg[-1]
    backed = (use_kernels and op.fused_hint is not None
              and op.fused_hint in _FUSED_KERNELS)
    return op.fused_hint if backed else None


def fuse_non_gemm(graph: OpGraph, use_kernels: bool = True) -> OpGraph:
    """The paper's C5 pass: merge maximal non-GEMM runs per module.

    A *run* is a maximal sequence of consecutive (in topo order) non-GEMM
    ops of the same module; each run becomes one ``FusedOp`` (values
    consumed outside stay exposed, everything else is VMEM-internal) —
    except that contiguous sub-runs carrying a registered-kernel hint are
    emitted as their own group so the Pallas kernel can serve them.
    """
    fused = OpGraph(graph.graph_inputs)
    fused.meta = dict(graph.meta)
    ops = graph.ops
    i = 0
    group_id = 0
    while i < len(ops):
        op = ops[i]
        if isinstance(op, FusedOp) or op.is_gemm:
            fused.add(op)
            i += 1
            continue
        # maximal same-module non-GEMM run
        j = i
        run: list[Op] = []
        while (j < len(ops) and not ops[j].is_gemm
               and not isinstance(ops[j], FusedOp)
               and ops[j].module == op.module):
            run.append(ops[j])  # type: ignore[arg-type]
            j += 1
        for seg in _segment_by_kernel_hint(run, use_kernels):
            _emit_group(fused, graph, seg, group_id, use_kernels)
            group_id += 1
        i = j
    return fused
