"""Version-tolerance shims over the moving parts of the jax API.

The repo targets the jax/Pallas toolchain baked into its image, but jax has
renamed two surfaces this code relies on:

* ``shard_map`` lives at ``jax.shard_map`` on new releases and at
  ``jax.experimental.shard_map.shard_map`` on older ones, and its
  replication-check kwarg was renamed ``check_rep`` → ``check_vma``.
* ``jax.make_mesh`` grew an ``axis_types`` kwarg (with
  ``jax.sharding.AxisType``) that older releases reject.

Import from here instead of from jax directly; call sites may use either
kwarg spelling and it is translated to whatever the installed jax accepts.
"""

from __future__ import annotations

import inspect

import jax

__all__ = ["shard_map", "make_mesh", "cost_analysis"]

try:                                      # jax >= 0.6
    from jax import shard_map as _shard_map
except ImportError:                       # older: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, **kwargs):
    """``shard_map(f, mesh=..., in_specs=..., out_specs=..., ...)``.

    Accepts both ``check_vma`` (new) and ``check_rep`` (old) and forwards
    the one the installed jax understands; drops the flag entirely if
    neither name exists.
    """
    for ours, theirs in (("check_vma", "check_rep"),
                         ("check_rep", "check_vma")):
        if ours in kwargs and ours not in _SHARD_MAP_PARAMS:
            val = kwargs.pop(ours)
            if theirs in _SHARD_MAP_PARAMS:
                kwargs.setdefault(theirs, val)
    return _shard_map(f, **kwargs)


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict on every jax version
    (older releases return a one-element list of per-program dicts)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def make_mesh(axis_shapes, axis_names) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with ``Auto`` axis types when this jax has them."""
    kwargs = {}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if (axis_type is not None and
            "axis_types" in inspect.signature(jax.make_mesh).parameters):
        kwargs["axis_types"] = (axis_type.Auto,) * len(axis_names)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)
