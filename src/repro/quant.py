"""Shared int8 symmetric (absmax) quantization helpers.

One quantization codepath for every int8 wire format in the repo:

* **embedding rows** — ``repro.embedding`` stores built with
  ``row_dtype="int8"`` hold cache/backing/staging rows as int8 with one
  fp32 scale per row; dequantization happens inside the Pallas gathers
  (``repro.kernels.multi_table_lookup``), so the fp32 row never exists in
  memory, only in registers. ~4× gather/h2d bandwidth at d=32.
* **gradient compression** — ``repro.training.compression`` quantizes
  per-256-element blocks with a rank-shared scale for the data-parallel
  all-reduce.
* **MLP weights** — plans compiled with ``compute_dtype="int8"`` hold each
  dense-branch weight matrix as int8 with one fp32 scale per *output
  channel* (``quantize_channels``); the fused ``dense_matmul_q8`` kernel
  accumulates int8×int8→int32 and dequantizes in the epilogue, so the
  fp32 weight never exists at serve time.

Symmetric absmax: ``scale = max|x| / 127`` (the -128 code is unused so the
grid is symmetric around an *exact* zero), ``q = clip(round(x / scale))``.
Round-trip error is bounded by ``scale / 2`` per element (round to
nearest); all-zero rows get the ``SCALE_EPS`` floor so they quantize to
``q = 0`` and dequantize to exactly ``0.0`` — the multi-hot masking zero
row stays a true zero through the int8 tier.

Every helper works on both jnp arrays (device tensors — store init/adopt/
refresh) and numpy arrays (host tensors — the ``HostBackedStore`` backing
and the prefetch pipeline's staging buffer), with identical semantics
(both round half to even).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

__all__ = ["QMAX", "SCALE_EPS", "absmax_scale", "quantize", "dequantize",
           "quantize_rows", "dequantize_rows",
           "quantize_channels", "dequantize_channels"]

#: symmetric int8 range [-127, 127]; -128 is deliberately unused
QMAX = 127.0
#: floor for all-zero blocks/rows: q = 0 and dequant = 0 exactly
SCALE_EPS = 1e-12


def _xp(*arrays):
    """numpy for host arrays, jnp otherwise (semantics are identical)."""
    return np if all(isinstance(a, np.ndarray) for a in arrays) else jnp


def absmax_scale(x, axis=-1):
    """Per-slice symmetric scale ``max|x| / QMAX`` (keepdims), floored at
    ``SCALE_EPS`` so all-zero slices round-trip to exact zero."""
    xp = _xp(x)
    s = xp.max(xp.abs(x), axis=axis, keepdims=True) / QMAX
    return xp.maximum(s, SCALE_EPS).astype(xp.float32)


def quantize(x, scale):
    """``clip(round(x / scale), -127, 127)`` as int8. ``scale`` broadcasts
    (typically the keepdims output of :func:`absmax_scale`)."""
    xp = _xp(x)
    q = xp.clip(xp.round(x / scale), -QMAX, QMAX)
    return q.astype(xp.int8)


def dequantize(q, scale):
    """``q * scale`` in float32 (q may be int8 or the int32-widened psum
    payload of the compressed all-reduce)."""
    xp = _xp(q)
    return q.astype(xp.float32) * scale


def quantize_rows(table):
    """Quantize a (rows, d) table row-wise.

    Returns ``(q, scale)``: ``q`` (rows, d) int8 and ``scale`` (rows, 1)
    float32 — the layout the quantized embedding stores keep per tier and
    the Pallas gathers ride through their scalar-prefetch index maps.
    """
    scale = absmax_scale(table, axis=-1)
    return quantize(table, scale), scale


def dequantize_rows(q, scale):
    """Inverse of :func:`quantize_rows`: (rows, d) int8 × (rows, 1) f32
    -> (rows, d) float32."""
    return dequantize(q, scale)


def quantize_channels(w):
    """Quantize a (fan_in, fan_out) dense weight per *output channel*.

    The per-channel (``axis=0``) twin of :func:`quantize_rows`: each output
    column gets its own absmax scale, so one outlier channel cannot crush
    the resolution of every other channel — the standard weight layout for
    int8 matmuls (the scale broadcasts over the int32 accumulator columns
    in the kernel epilogue).

    Returns ``(q, scale)``: ``q`` (fan_in, fan_out) int8 and ``scale``
    (1, fan_out) float32.
    """
    scale = absmax_scale(w, axis=0)
    return quantize(w, scale), scale


def dequantize_channels(q, scale):
    """Inverse of :func:`quantize_channels`: (fan_in, fan_out) int8 ×
    (1, fan_out) f32 -> (fan_in, fan_out) float32."""
    return dequantize(q, scale)
