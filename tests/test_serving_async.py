"""Async serving runtime tests (ISSUE-3 acceptance surface).

Covers: futures-based intake (resolution values, submit order, latency
stamps), the background worker draining a ``TimeoutBatch`` SLO without
caller polling, refresh-without-recompile (plan-cache keys identical, zero
new compiles, bit-exact vs ``DenseStore`` across ≥2 refreshes under zipf
traffic), thread-safe stats with ``queue_depth``, the multi-model
``ServingRuntime`` router, and the absence of the removed deprecated
surfaces (``core.fused_embedding``, ``CTRServingEngine``).
"""

import importlib
import sys
import threading

import numpy as np
import pytest
import jax

from repro.configs import ctr_spec
from repro.data.synthetic import CRITEO, zipf_ids
from repro.embedding import CachedStore
from repro.models.ctr import CTR_MODELS
from repro.serving import (BucketedBatch, FixedBatch, InferenceEngine,
                           RequestFuture, ServingRuntime, TimeoutBatch)

SCHEMA = CRITEO.scaled(2_000)
SPEC_KW = dict(embed_dim=8, hidden=64, max_field=2_000)


def make(model_name="widedeep"):
    spec = ctr_spec(model_name, "criteo", **SPEC_KW)
    model = CTR_MODELS[model_name](spec)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def rows_of(n, seed=0):
    rng = np.random.default_rng(seed)
    return [np.array([rng.integers(0, s) for s in SCHEMA.field_sizes],
                     dtype=np.int32) for _ in range(n)]


def zipf_rows(n, seed=0, exponent=1.1):
    return list(np.asarray(zipf_ids(jax.random.PRNGKey(seed), n,
                                    SCHEMA.field_sizes, exponent=exponent)))


def direct_scores(model, params, rows):
    import jax.numpy as jnp
    return np.asarray(model.predict_proba(params,
                                          jnp.asarray(np.stack(rows))))


# --- futures ------------------------------------------------------------------

def test_submit_returns_future_resolved_by_sync_drain():
    model, params = make()
    eng = InferenceEngine(model, params, policy=FixedBatch(8))
    rows = rows_of(8)
    futs = eng.submit_many(rows)
    assert all(isinstance(f, RequestFuture) and not f.done() for f in futs)
    drained = eng.serve_pending()
    assert all(f.done() for f in futs)
    got = np.array([f.result() for f in futs])
    np.testing.assert_array_equal(got, drained)
    np.testing.assert_allclose(got, direct_scores(model, params, rows),
                               rtol=1e-5, atol=1e-5)
    assert all(f.latency_ms is not None and f.latency_ms >= 0 for f in futs)


def test_future_result_times_out_when_unserved():
    model, params = make()
    eng = InferenceEngine(model, params, policy=FixedBatch(8))
    fut = eng.submit(rows_of(1)[0])
    with pytest.raises(TimeoutError):
        fut.result(timeout=0.01)


def test_futures_resolve_in_submit_order_under_worker():
    """ISSUE-3 satellite: the worker resolves futures FIFO — within each
    batch and across batches — observed via done-callbacks."""
    model, params = make()
    eng = InferenceEngine(model, params, policy=BucketedBatch((8, 16)))
    eng.warmup()
    rows = rows_of(43)
    resolved = []
    lock = threading.Lock()
    eng.start()
    try:
        futs = eng.submit_many(rows)
        for i, f in enumerate(futs):
            f.add_done_callback(
                lambda fut, _i=i: (lock.acquire(), resolved.append(_i),
                                   lock.release()))
        got = np.array([f.result(timeout=60.0) for f in futs])
    finally:
        eng.stop()
    # every request resolved exactly once, in submit order
    assert sorted(resolved) == list(range(43))
    within_batch_sorted = all(resolved[i] < resolved[i + 1]
                              for i in range(len(resolved) - 1))
    assert within_batch_sorted, resolved
    np.testing.assert_allclose(got, direct_scores(model, params, rows),
                               rtol=1e-5, atol=1e-5)


# --- background worker --------------------------------------------------------

def test_worker_fires_timeout_slo_without_polling():
    """ISSUE-3 satellite: a partial batch inside a TimeoutBatch window is
    drained by the worker once the oldest request ages past the SLO —
    no serve_pending/flush call anywhere."""
    model, params = make()
    eng = InferenceEngine(
        model, params,
        policy=TimeoutBatch(FixedBatch(8), max_wait_ms=25.0),
        worker_tick_ms=1.0)
    eng.warmup()
    eng.start()
    try:
        rows = rows_of(3)
        futs = eng.submit_many(rows)           # partial: below the bucket
        got = np.array([f.result(timeout=60.0) for f in futs])
    finally:
        eng.stop()
    st = eng.stats
    assert st.n_batches == 1 and st.batches_per_bucket == {8: 1}
    assert st.n_requests == 3 and eng.pending() == 0
    np.testing.assert_allclose(got, direct_scores(model, params, rows),
                               rtol=1e-5, atol=1e-5)
    # queued → served latency must cover the SLO wait the policy imposed
    assert st.p50_ms >= 25.0


def test_worker_drains_full_buckets_immediately():
    model, params = make()
    eng = InferenceEngine(
        model, params,
        policy=TimeoutBatch(FixedBatch(8), max_wait_ms=60_000.0))
    eng.warmup()
    eng.start()
    try:
        futs = eng.submit_many(rows_of(16))    # two full buckets: no SLO wait
        for f in futs:
            f.result(timeout=60.0)
    finally:
        eng.stop(flush=False)
    assert eng.stats.n_batches == 2
    assert eng.stats.queue_depth == 0


def test_start_stop_lifecycle_idempotent_and_flushing():
    model, params = make()
    eng = InferenceEngine(
        model, params,
        policy=TimeoutBatch(FixedBatch(8), max_wait_ms=60_000.0))
    eng.start()
    eng.start()                                 # idempotent
    assert eng.running
    futs = eng.submit_many(rows_of(3))          # held by the SLO window
    eng.stop()                                  # join + flush leftovers
    assert not eng.running
    assert all(f.done() for f in futs)
    assert eng.pending() == 0
    eng.stop()                                  # idempotent after stop


def test_sync_surface_still_works_alongside_worker_api():
    """serve_pending/flush/predict remain the sync surface when no worker
    is started — exact pre-async behaviour."""
    model, params = make()
    eng = InferenceEngine(model, params, policy=BucketedBatch((8, 16)))
    eng.submit_many(rows_of(20))
    scores = np.concatenate([eng.serve_pending(), eng.flush()])
    assert scores.shape == (20,)
    assert eng.stats.queue_depth == 0


# --- stats thread-safety (ISSUE-3 satellite) ---------------------------------

def test_stats_expose_queue_depth():
    model, params = make()
    eng = InferenceEngine(model, params, policy=FixedBatch(8))
    eng.submit_many(rows_of(5))
    assert eng.stats.queue_depth == 5
    eng.flush()
    assert eng.stats.queue_depth == 0


def test_concurrent_submitters_with_worker_lose_no_request():
    """Counters stay consistent with many submitter threads racing the
    worker: every request served exactly once, totals add up."""
    model, params = make()
    eng = InferenceEngine(model, params, policy=BucketedBatch((8, 16)),
                          worker_tick_ms=0.2)
    eng.warmup()
    eng.start()
    futs_per_thread = {}

    def submitter(tid):
        futs_per_thread[tid] = eng.submit_many(rows_of(24, seed=tid))

    threads = [threading.Thread(target=submitter, args=(t,))
               for t in range(4)]
    try:
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        all_futs = [f for fs in futs_per_thread.values() for f in fs]
        for f in all_futs:
            f.result(timeout=60.0)
    finally:
        eng.stop()
    st = eng.stats
    assert st.n_requests == 4 * 24
    assert st.queue_depth == 0 and eng.pending() == 0
    assert sum(st.batches_per_bucket.values()) == st.n_batches
    assert eng.worker_error is None
    # per-thread scores match the direct forward (routing never mixed rows)
    for tid, futs in futs_per_thread.items():
        got = np.array([f.result() for f in futs])
        np.testing.assert_allclose(
            got, direct_scores(model, params, rows_of(24, seed=tid)),
            rtol=1e-5, atol=1e-5)


def test_malformed_row_fails_batch_futures_instead_of_hanging():
    """A ragged row in a batch must fail that batch's futures (stack
    raises before compute) — never strand them unresolved."""
    model, params = make()
    eng = InferenceEngine(model, params, policy=FixedBatch(4))
    futs = eng.submit_many(rows_of(3))
    bad = eng.submit(np.zeros(len(SCHEMA.field_sizes) + 1, dtype=np.int32))
    with pytest.raises(ValueError):
        eng.flush()
    for f in futs + [bad]:
        assert f.done()
        with pytest.raises(ValueError):
            f.result(timeout=0)


def test_raising_done_callback_does_not_strand_other_futures():
    model, params = make()
    eng = InferenceEngine(model, params, policy=FixedBatch(8))
    futs = eng.submit_many(rows_of(8))
    futs[0].add_done_callback(lambda f: 1 / 0)     # hostile callback
    seen = []
    futs[1].add_done_callback(lambda f: seen.append(f.result()))
    eng.serve_pending()
    assert all(f.done() for f in futs)             # nobody left hanging
    assert seen == [futs[1].result()]


# --- refresh-without-recompile (ISSUE-3 satellite + acceptance) ---------------

def test_refresh_without_recompile_bit_exact_zipf():
    """≥2 refreshes under zipf traffic: plan-cache keys identical, zero new
    compiles, scores bit-exact vs DenseStore throughout."""
    model_d, params_d = make()
    dense = InferenceEngine(model_d, params_d, policy=BucketedBatch((8, 16)))

    model_c, params_c = make()
    store = CachedStore(model_c.spec.embedding_spec(), capacity=128)
    eng = InferenceEngine(model_c, params_c, policy=BucketedBatch((8, 16)),
                          store=store)
    eng.warmup()
    keys0 = set(eng.cached_plans)
    compiles0 = eng.stats.cache_misses

    for round_ in range(3):
        rows = zipf_rows(24, seed=round_)
        want = dense.predict(np.stack(rows))
        eng.submit_many(rows)
        got = eng.serve_pending()
        np.testing.assert_array_equal(got, want)   # bit-exact, every round
        eng.refresh_cache()                        # swap tensors, keep plans
        assert set(eng.cached_plans) == keys0      # identical cache keys
        assert eng.stats.cache_misses == compiles0  # zero new compiles

    assert store.stats.refreshes >= 2
    assert eng.stats.emb_cache_refreshes >= 2
    # after refreshes the index map tracks the zipf head: hot traffic mass
    # should be covered by the cache
    assert eng.stats.emb_cached_traffic_fraction > 0.0


def test_plan_runtime_inputs_exposed():
    """Plans compiled against a refreshable store advertise the store
    tensors they take per call; dense plans advertise none."""
    from repro.core import compile_plan
    model_d, params_d = make()
    assert compile_plan(model_d, params_d, "dual", 8).runtime_inputs == ()

    model_c, params_c = make()
    store = CachedStore(model_c.spec.embedding_spec(), capacity=64)
    params_c = model_c.use_store(store, params_c)
    plan = compile_plan(model_c, params_c, "dual", 8)
    assert plan.runtime_inputs == ("emb:backing", "emb:cache",
                                   "emb:slot_of_row")


def test_refresh_under_running_worker_stays_exact():
    """Refresh concurrently with a draining worker: the double-buffered
    publish means every batch reads a consistent (old or new) tensor set
    — scores stay bit-exact with the dense reference."""
    model_d, params_d = make()
    dense = InferenceEngine(model_d, params_d, policy=FixedBatch(8))
    rows = zipf_rows(64, seed=7)
    want = dense.predict(np.stack(rows))

    model_c, params_c = make()
    store = CachedStore(model_c.spec.embedding_spec(), capacity=128)
    eng = InferenceEngine(model_c, params_c, policy=FixedBatch(8),
                          store=store, refresh_every=2)  # refresh mid-stream
    eng.warmup()
    eng.start()
    try:
        futs = eng.submit_many(rows)
        got = np.array([f.result(timeout=60.0) for f in futs])
    finally:
        eng.stop()
    np.testing.assert_array_equal(got, want)
    assert store.stats.refreshes >= 2
    assert eng.stats.cache_misses == 1             # the single warmed bucket


# --- multi-model runtime (acceptance) ----------------------------------------

def test_runtime_routes_two_models_async_bit_exact():
    """Acceptance: ServingRuntime serves 2 models concurrently through the
    async intake with per-model stats and bit-exact scores vs the
    synchronous path."""
    rt = ServingRuntime()
    built = {}
    for name in ("widedeep", "dcn"):
        model, params = make(name)
        built[name] = (model, params)
        rt.add_model(name, model, params,
                     policy=TimeoutBatch(BucketedBatch((8, 16)),
                                         max_wait_ms=5.0),
                     worker_tick_ms=1.0)
    assert rt.models == ("widedeep", "dcn")
    rt.warmup()
    rt.start()
    try:
        futs = {n: rt.submit_many(n, rows_of(21, seed=i))
                for i, n in enumerate(rt.models)}
        got = {n: np.array([f.result(timeout=60.0) for f in fs])
               for n, fs in futs.items()}
    finally:
        rt.stop()
    for i, name in enumerate(rt.models):
        model, params = built[name]
        # bit-exact vs the synchronous engine path on the same rows
        sync_eng = InferenceEngine(model, params,
                                   policy=BucketedBatch((8, 16)))
        sync_eng.submit_many(rows_of(21, seed=i))
        want = np.concatenate([sync_eng.serve_pending(), sync_eng.flush()])
        np.testing.assert_array_equal(got[name], want)
        # per-model stats kept separately
        assert rt.engine(name).stats.n_requests == 21
    agg = rt.stats()
    assert agg.n_models == 2 and agg.n_requests == 42
    assert agg.queue_depth == 0
    # per_model is a consistent snapshot, not the live (mutating) object
    snap = agg.per_model["widedeep"]
    live = rt.engine("widedeep").stats
    assert snap is not live
    assert snap.n_requests == live.n_requests == 21
    rt.engine("widedeep").predict(rows_of(1)[0])
    assert snap.n_requests == 21          # later traffic never mutates it
    assert agg.p99_ms >= agg.p50_ms >= 0.0


def test_runtime_rejects_unknown_and_duplicate_models():
    rt = ServingRuntime()
    model, params = make()
    rt.add_model("widedeep", model, params, policy=FixedBatch(8))
    with pytest.raises(ValueError, match="already registered"):
        rt.add_engine("widedeep",
                      InferenceEngine(model, params, policy=FixedBatch(8)))
    with pytest.raises(KeyError, match="widedeep"):
        rt.submit("nope", rows_of(1)[0])


def test_runtime_shared_admission_refreshes_all_stores():
    """refresh_every counts submitted traffic across models and swaps
    every refreshable store's cache (asynchronously — the crossing submit
    never pays the rebuild) — without dropping any plans."""
    import time as _time

    def wait_refreshes(stores, n, deadline_s=30.0):
        t0 = _time.perf_counter()
        while _time.perf_counter() - t0 < deadline_s:
            if all(s.stats.refreshes >= n for s in stores.values()):
                return
            _time.sleep(0.005)
        raise AssertionError(
            f"stores never reached {n} refreshes: "
            f"{[s.stats.refreshes for s in stores.values()]}")

    rt = ServingRuntime(refresh_every=16)
    stores = {}
    for name in ("widedeep", "dcn"):
        model, params = make(name)
        stores[name] = CachedStore(model.spec.embedding_spec(), capacity=64)
        rt.add_model(name, model, params, policy=FixedBatch(8),
                     store=stores[name])
    rt.warmup()
    plans = {n: set(rt.engine(n).cached_plans) for n in rt.models}
    for i in range(2):                       # 2×16 submits → 2 shared refreshes
        for name in rt.models:
            rt.submit_many(name, rows_of(8, seed=i))
        rt.flush()
        wait_refreshes(stores, i + 1)        # refresh runs off-thread
    assert all(s.stats.refreshes == 2 for s in stores.values())
    for n in rt.models:                      # plan caches survived both swaps
        assert set(rt.engine(n).cached_plans) == plans[n]
        assert rt.engine(n).stats.cache_misses == 1


# --- removed deprecated surfaces (ISSUE-6 satellite) -------------------------

def test_deprecated_surfaces_are_gone():
    """The fused_embedding shim and the CTRServingEngine alias were removed
    — only the real surfaces (repro.embedding, InferenceEngine + policies)
    remain importable."""
    sys.modules.pop("repro.core.fused_embedding", None)
    with pytest.raises(ImportError):
        importlib.import_module("repro.core.fused_embedding")
    import repro.serving as serving
    assert not hasattr(serving, "CTRServingEngine")
    assert not hasattr(serving, "ServeStats")
