"""Distribution-layer tests on a small host-device mesh.

conftest does NOT set the 512-device flag (smoke tests must see 1 device);
this module spawns its own 8-device context by running in a subprocess-like
guarded fixture: we set the flag via a dedicated pytest plugin-level env in
``tests/distributed_inner.py`` executed under ``python -m``.
"""

import json
import os
import subprocess
import sys

import pytest

INNER = os.path.join(os.path.dirname(__file__), "distributed_inner.py")


@pytest.mark.parametrize("case", ["sharded_lookup", "compressed_psum",
                                  "flash_decode", "param_specs",
                                  "cell_lowering"])
def test_distributed(case):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    out = subprocess.run([sys.executable, INNER, case], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"{case} failed:\n{out.stdout}\n{out.stderr}"
    assert f"{case} OK" in out.stdout, out.stdout
