"""End-to-end behaviour tests for the DPIFrame system.

Covers: the Fig.-8 level ladder (numerical invariance), Alg.-2 scheduling,
C5 fusion bookkeeping, training convergence + checkpoint/restart, the
serving engine, and pipeline determinism (fault-tolerance substrate).
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ctr_spec
from repro.core import DualParallelExecutor
from repro.data.synthetic import CRITEO, synthetic_batch
from repro.models.ctr import CTR_MODELS
from repro.training import (AdamWConfig, TrainLoopConfig, adamw_init,
                            adamw_update, roc_auc, run_train_loop,
                            latest_step, restore_checkpoint, save_checkpoint)

SCHEMA = CRITEO.scaled(2_000)
SPEC_KW = dict(embed_dim=8, hidden=64, max_field=2_000)


def make(model_name):
    spec = ctr_spec(model_name, "criteo", **SPEC_KW)
    model = CTR_MODELS[model_name](spec)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


@pytest.mark.parametrize("model_name", list(CTR_MODELS))
def test_level_ladder_is_numerically_invariant(model_name):
    """Paper Table I: DPIFrame is a pure re-scheduling layer."""
    model, params = make(model_name)
    batch = synthetic_batch(SCHEMA, 0, 64)
    outs = {}
    for level in ("naive", "fused_emb", "fused_all", "dual"):
        ex = DualParallelExecutor(model.build_graph, level=level)
        outs[level] = np.asarray(ex.build(params)({"ids": batch["ids"]}))
    for level, out in outs.items():
        np.testing.assert_allclose(out, outs["naive"], rtol=1e-5, atol=1e-6,
                                   err_msg=level)


@pytest.mark.parametrize("model_name", list(CTR_MODELS))
def test_fusion_reduces_dispatch_count(model_name):
    model, params = make(model_name)
    naive = DualParallelExecutor(model.build_graph, level="naive")
    naive.prepare(params)
    dual = DualParallelExecutor(model.build_graph, level="dual")
    dual.prepare(params)
    assert dual.stats.n_ops_after < naive.stats.n_ops_after
    assert dual.stats.schedule_policy == "breadth_first"


def test_breadth_first_queue_interleaves_branches():
    model, params = make("dcnv2")
    ex = DualParallelExecutor(model.build_graph, level="dual")
    graph, order = ex.prepare(params)
    # both branches appear within the first two queue slots
    mods = {graph.op(name).module for name in ex.stats.queue[:2]}
    assert mods == {"explicit", "implicit"}
    assert graph.is_valid_order(order)


def test_branch_order_ablation_changes_queue_head():
    model, params = make("deepfm")
    heads = {}
    for order in ("explicit_first", "implicit_first"):
        ex = DualParallelExecutor(model.build_graph, level="dual",
                                  branch_order=order)
        graph, _ = ex.prepare(params)
        heads[order] = graph.op(ex.stats.queue[0]).module
    assert heads["explicit_first"] == "explicit"
    assert heads["implicit_first"] == "implicit"


def test_training_learns_and_metrics_improve():
    model, params = make("dcnv2")
    opt = AdamWConfig(lr=3e-3)
    state = adamw_init(params, opt)

    @jax.jit
    def step_fn(state, batch):
        loss, grads = jax.value_and_grad(model.loss)(state.params, batch)
        state, m = adamw_update(state, grads, opt)
        return state, {"loss": loss, **m}

    losses = []
    for s in range(120):
        state, m = step_fn(state, synthetic_batch(SCHEMA, s, 256))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10])
    val = synthetic_batch(SCHEMA, 999, 2048)
    probs = np.asarray(model.predict_proba(state.params, val["ids"]))
    auc = roc_auc(np.asarray(val["labels"]), probs)
    assert auc > 0.55, f"planted signal not learned (auc={auc})"


def test_checkpoint_restart_resumes_exactly(tmp_path):
    model, params = make("dcn")
    opt = AdamWConfig(lr=1e-3)

    @jax.jit
    def step_fn(state, batch):
        loss, grads = jax.value_and_grad(model.loss)(state.params, batch)
        state, m = adamw_update(state, grads, opt)
        return state, {"loss": loss, **m}

    batch_fn = lambda s: synthetic_batch(SCHEMA, s, 64)
    cfg = TrainLoopConfig(total_steps=6, ckpt_every=3,
                          ckpt_dir=str(tmp_path / "a"), log_every=100)
    s1, _ = run_train_loop(step_fn, adamw_init(params, opt), batch_fn, cfg)
    # interrupted run: 3 steps, then a fresh loop resumes from the ckpt
    cfg2 = TrainLoopConfig(total_steps=3, ckpt_every=3,
                           ckpt_dir=str(tmp_path / "b"), log_every=100)
    run_train_loop(step_fn, adamw_init(params, opt), batch_fn, cfg2)
    cfg3 = TrainLoopConfig(total_steps=6, ckpt_every=3,
                           ckpt_dir=str(tmp_path / "b"), log_every=100)
    s2, _ = run_train_loop(step_fn, adamw_init(params, opt), batch_fn, cfg3)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_checkpoint_atomicity(tmp_path):
    tree = {"w": jnp.arange(10.0), "b": jnp.ones((3, 3))}
    save_checkpoint(str(tmp_path), 5, tree)
    # a stale tmp dir from a crashed writer must be ignored
    (tmp_path / ".tmp_step_7").mkdir()
    assert latest_step(str(tmp_path)) == 5
    back = restore_checkpoint(str(tmp_path), 5, tree)
    np.testing.assert_allclose(np.asarray(back["w"]), np.asarray(tree["w"]))


def test_serving_engine_batches_and_pads():
    from repro.serving import FixedBatch, InferenceEngine
    model, params = make("widedeep")
    eng = InferenceEngine(model, params, policy=FixedBatch(32), level="dual")
    eng.warmup()
    rng = np.random.default_rng(0)
    n = 50   # 32 + 18 (padded partial batch)
    rows = [np.array([rng.integers(0, s) for s in SCHEMA.field_sizes],
                     dtype=np.int32) for _ in range(n)]
    for r in rows:
        eng.submit(r)
    scores = eng.serve_pending()
    assert scores.shape == (n,)
    # sigmoid saturates to exactly 0.0/1.0 in f32 for |logit| > ~17
    assert np.all((scores >= 0) & (scores <= 1))
    assert eng.stats.n_batches == 2
    direct = np.asarray(model.predict_proba(params,
                                            jnp.asarray(np.stack(rows))))
    np.testing.assert_allclose(scores, direct, rtol=1e-5, atol=1e-5)


def test_data_pipeline_determinism():
    a = synthetic_batch(SCHEMA, 7, 32)
    b = synthetic_batch(SCHEMA, 7, 32)
    assert np.array_equal(np.asarray(a["ids"]), np.asarray(b["ids"]))
    c = synthetic_batch(SCHEMA, 8, 32)
    assert not np.array_equal(np.asarray(a["ids"]), np.asarray(c["ids"]))
