"""Quantized embedding tier tests — int8 rows with in-kernel dequant.

Acceptance surface: ``row_dtype="int8"`` on ``CachedStore`` /
``HostBackedStore`` serves within the per-row grid-step bound of the fp32
``DenseStore`` (one-hot + multi-hot, pre and post ``refresh()``, on a
simulated mesh with the scale leaves replicated like ``slot_of_row``),
moves ``d + 4`` wire bytes per row instead of ``4·d``, keeps refreshes
recompile-free (the scales are runtime plan inputs), and the fp32 default
stays bit-exact and untouched. The shared absmax helpers in ``repro.quant``
round-trip within half a grid step and keep zero rows exactly zero.
"""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import quant
from repro.compat import make_mesh
from repro.configs import ctr_spec
from repro.data.synthetic import CRITEO, zipf_ids
from repro.embedding import (CachedStore, DenseStore,
                             FusedEmbeddingCollection, FusedEmbeddingSpec,
                             HostBackedStore)
from repro.models.ctr import CTR_MODELS
from repro.serving import FixedBatch, InferenceEngine

SPEC = FusedEmbeddingSpec(field_sizes=(60, 7, 350, 90), dim=8)
SCHEMA = CRITEO.scaled(2_000)
SPEC_KW = dict(embed_dim=8, hidden=64, max_field=2_000)


def needs(n):
    return pytest.mark.skipif(
        jax.device_count() < n,
        reason=f"needs {n} devices (run under XLA_FLAGS="
               "--xla_force_host_platform_device_count=8)")


def traffic(batch=128, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(np.stack([rng.integers(0, s, size=batch)
                                 for s in SPEC.field_sizes], axis=1),
                       dtype=jnp.int32)


def grid_bound(table, ids, offsets):
    """Per-element error bound of the int8 round trip: half a grid step
    of each gathered row's absmax scale (+ fp slack)."""
    scale = np.asarray(quant.absmax_scale(np.asarray(table)))
    rows = np.asarray(ids) + np.asarray(offsets)[None, :]
    return scale[rows] * 0.5 + 1e-6


# --- repro.quant helpers ----------------------------------------------------

def test_quant_round_trip_within_half_grid_step():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(50, 16)).astype(np.float32) * 0.3
    q, scale = quant.quantize_rows(x)
    assert q.dtype == np.int8 and scale.shape == (50, 1)
    err = np.abs(quant.dequantize_rows(q, scale) - x)
    assert np.all(err <= scale * 0.5 + 1e-7)


def test_quant_zero_rows_round_trip_to_exact_zero():
    x = np.zeros((4, 8), np.float32)
    q, scale = quant.quantize_rows(x)
    assert np.all(q == 0) and np.all(scale > 0)   # eps-floored, not 0/0
    assert np.all(quant.dequantize_rows(q, scale) == 0.0)


def test_quant_symmetric_grid_never_uses_minus_128():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(100, 16)).astype(np.float32) * 10.0
    q, _ = quant.quantize_rows(x)
    assert q.min() >= -127 and q.max() <= 127


def test_quant_jnp_and_numpy_agree():
    rng = np.random.default_rng(2)
    x = rng.normal(size=(20, 8)).astype(np.float32)
    qn, sn = quant.quantize_rows(x)
    qj, sj = quant.quantize_rows(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(qj), qn)
    np.testing.assert_array_equal(np.asarray(sj), sn)


# --- spec surface -----------------------------------------------------------

def test_spec_wire_row_bytes():
    assert SPEC.wire_row_bytes == SPEC.dim * 4 and not SPEC.quantized
    q = dataclasses.replace(SPEC, row_dtype="int8")
    assert q.quantized and q.wire_row_bytes == SPEC.dim + 4


def test_spec_rejects_unknown_row_dtype():
    with pytest.raises(ValueError):
        dataclasses.replace(SPEC, row_dtype="int4")


def test_describe_distinguishes_quantized_stores():
    """PlanKeys hash store.describe(): fp32 and int8 stores over the same
    spec must never collide in an engine's plan cache."""
    fp = CachedStore(SPEC, capacity=16)
    q8 = CachedStore(SPEC, capacity=16, row_dtype="int8")
    assert fp.describe() != q8.describe() and ",int8" in q8.describe()
    hq = HostBackedStore(SPEC, capacity=16, row_dtype="int8")
    assert ",int8" in hq.describe()


# --- store-level parity vs fp32 DenseStore ----------------------------------

@pytest.mark.parametrize("store_cls", [CachedStore, HostBackedStore])
def test_quantized_store_within_grid_bound_of_dense(store_cls):
    dense = FusedEmbeddingCollection(SPEC)
    pd = dense.init(jax.random.PRNGKey(0))
    store = store_cls(SPEC, capacity=48, row_dtype="int8")
    coll = FusedEmbeddingCollection(SPEC, store=store)
    pq = store.from_dense(pd)
    ids = traffic()
    if store_cls is HostBackedStore:
        pq = store.stage(pq, np.asarray(ids))     # resolve misses first
    want = np.asarray(dense.apply(pd, ids, strategy="jnp"))
    got = np.asarray(coll.apply(pq, ids, strategy="jnp"))
    bound = grid_bound(dense.dense_view(pd), ids,
                       SPEC.offsets).repeat(SPEC.dim, axis=-1)
    assert np.all(np.abs(got - want).reshape(bound.shape) <= bound)
    # Pallas kernel twin agrees with the jnp twin on the same int8 grid
    got_pl = np.asarray(coll.apply(pq, ids[:16], strategy="pallas",
                                   interpret=True))
    np.testing.assert_allclose(got_pl, got[:16], rtol=1e-6, atol=1e-6)


def test_quantized_store_multihot_within_pooled_bound():
    h = 3
    dense = FusedEmbeddingCollection(SPEC)
    pd = dense.init(jax.random.PRNGKey(0))
    store = CachedStore(SPEC, capacity=48, row_dtype="int8")
    coll = FusedEmbeddingCollection(SPEC, store=store)
    pq = store.from_dense(pd)
    rng = np.random.default_rng(3)
    ids = jnp.asarray(
        np.stack([rng.integers(0, s, size=(32, h))
                  for s in SPEC.field_sizes], axis=1), dtype=jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, size=(32, SPEC.k, h)),
                       dtype=jnp.float32)
    want = np.asarray(dense.apply_multihot(pd, ids, mask, strategy="jnp"))
    got = np.asarray(coll.apply_multihot(pq, ids, mask, strategy="jnp"))
    scale = np.asarray(quant.absmax_scale(np.asarray(dense.dense_view(pd))))
    rows = np.asarray(ids) + np.asarray(SPEC.offsets)[None, :, None]
    pooled = ((scale[rows][..., 0] * 0.5 + 1e-6)
              * np.asarray(mask)).sum(axis=-1, keepdims=True)
    err = np.abs(got - want).reshape(32, SPEC.k, SPEC.dim)
    assert np.all(err <= pooled + 1e-6)


def test_quantized_refresh_is_value_stable():
    """All tiers copy the same int8 grid, so a refresh (tier re-election)
    never changes served values — equality, not tolerance."""
    store = CachedStore(SPEC, capacity=32, row_dtype="int8")
    coll = FusedEmbeddingCollection(SPEC, store=store)
    params = coll.init(jax.random.PRNGKey(1))
    ids = traffic(seed=4)
    before = np.asarray(coll.apply(params, ids, strategy="jnp"))
    coll.observe(np.asarray(ids + np.asarray(SPEC.offsets)[None, :]))
    params = store.refresh(params)
    after = np.asarray(coll.apply(params, ids, strategy="jnp"))
    np.testing.assert_array_equal(after, before)
    assert store.stats.refreshes == 1


def test_dense_store_adopts_quantized_subtree():
    """DenseStore.adopt reconstitutes fp32 rows from an int8 subtree —
    exactly the dequantized grid, the only values that remain."""
    store = CachedStore(SPEC, capacity=16, row_dtype="int8")
    pq = store.init(jax.random.PRNGKey(2))
    dense = DenseStore(SPEC)
    pd = dense.adopt(pq)
    want = quant.dequantize_rows(np.asarray(pq["backing"]),
                                 np.asarray(pq["backing_scale"]))
    np.testing.assert_array_equal(np.asarray(pd["mega_table"]), want)


def test_collection_accepts_quantized_store_over_fp32_spec():
    """row_dtype is a store-layout knob, not a schema change: a collection
    built from an fp32 spec accepts the int8 store of the same schema."""
    store = CachedStore(SPEC, capacity=16, row_dtype="int8")
    coll = FusedEmbeddingCollection(SPEC, store=store)
    assert coll.store is store
    with pytest.raises(ValueError):
        FusedEmbeddingCollection(
            dataclasses.replace(SPEC, dim=SPEC.dim * 2), store=store)


# --- engine: wire bytes, counters, recompile-free refresh -------------------

def make_engine_pair(store_cls, model_name="widedeep", capacity=64,
                     row_dtype="int8", batch=8, mesh=None, dim=8):
    kw = dict(SPEC_KW, embed_dim=dim)
    spec = ctr_spec(model_name, "criteo", **kw)
    dense_model = CTR_MODELS[model_name](spec)
    dense = InferenceEngine(dense_model,
                            dense_model.init(jax.random.PRNGKey(0)),
                            policy=FixedBatch(batch), mesh=mesh)
    model = CTR_MODELS[model_name](spec)
    params = model.init(jax.random.PRNGKey(0))
    store = store_cls(spec.embedding_spec(), capacity=capacity,
                      row_dtype=row_dtype)
    eng = InferenceEngine(model, params, policy=FixedBatch(batch),
                          store=store, mesh=mesh)
    return dense, eng, store


def zipf_stream(n, seed=0, exponent=1.1):
    return np.asarray(zipf_ids(jax.random.PRNGKey(seed), n,
                               SCHEMA.field_sizes, exponent=exponent))


@pytest.mark.parametrize("store_cls", [CachedStore, HostBackedStore])
def test_engine_serves_quantized_within_tolerance_no_recompiles(store_cls):
    dense, eng, store = make_engine_pair(store_cls)
    ids = zipf_stream(40)
    want = dense.predict(ids)
    for wave in np.array_split(ids, 2):
        eng.submit_many(list(wave))
        eng.serve_pending()
        eng.refresh_cache()                       # swap mid-stream
    got = np.concatenate([eng.predict(ids)])
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-2)
    assert store.stats.refreshes == 2
    assert eng.stats.cache_misses == 1            # compiled exactly once
    assert len(eng.cached_plans) == 1


def test_engine_mirrors_quant_counters():
    _, eng, store = make_engine_pair(CachedStore)
    ids = zipf_stream(16)
    eng.submit_many(list(ids))
    eng.serve_pending()
    s = eng.stats
    assert s.emb_quant_rows > 0
    assert s.emb_gather_bytes == store.stats.gather_bytes > 0
    assert s.emb_quant_bytes_saved == store.stats.quant_bytes_saved > 0
    # wire accounting: every gathered row moved d + 4 bytes, and the
    # saving per row is exactly 4·d − (d + 4)
    wire = store.wire_row_bytes
    assert s.emb_gather_bytes % wire == 0
    rows = s.emb_gather_bytes // wire
    assert s.emb_quant_bytes_saved == rows * (store.spec.dim * 4 - wire)


def test_host_resolved_wire_bytes_quarter_at_d32():
    """Same traffic, fp32 vs int8 host store at d=32: host→device wire
    traffic per resolved row shrinks by exactly 128/36. Uses the
    deterministic resolved count (staged + prefetched — the split between
    the two is a thread race, their union is the distinct miss set once
    staging exceeds it, mirroring the benchmark protocol)."""
    ids = zipf_stream(24, seed=5)
    out = {}
    for rd in (None, "int8"):
        spec = ctr_spec("widedeep", "criteo", **dict(SPEC_KW, embed_dim=32))
        emb = spec.embedding_spec()
        distinct = np.unique(ids + np.asarray(emb.offsets)[None, :]).size
        model = CTR_MODELS["widedeep"](spec)
        params = model.init(jax.random.PRNGKey(0))
        store = HostBackedStore(emb, capacity=64,
                                staging_capacity=distinct + 8 * emb.k,
                                row_dtype=rd)
        eng = InferenceEngine(model, params, policy=FixedBatch(8),
                              store=store)
        eng.submit_many(list(ids))
        eng.serve_pending()
        st = store.stats
        assert st.h2d_bytes % store.wire_row_bytes == 0
        resolved = st.staged_rows + st.prefetched_rows
        out[rd] = (resolved, resolved * store.wire_row_bytes)
    rows_fp, bytes_fp = out[None]
    rows_q8, bytes_q8 = out["int8"]
    assert rows_fp == rows_q8 > 0                 # tier choice is value-blind
    assert bytes_fp * 36 == bytes_q8 * 128        # exactly (d+4) vs 4·d


# --- mesh -------------------------------------------------------------------

@needs(8)
@pytest.mark.parametrize("shape,axes", [((2,), ("data",)),
                                        ((4, 2), ("data", "model"))])
def test_quantized_store_on_mesh_parity_with_dense(shape, axes):
    """int8 CachedStore on a real mesh: scores within tolerance of the
    fp32 dense engine on the same mesh, scale leaves replicated like
    slot_of_row, refresh recompile-free."""
    mesh = make_mesh(shape, axes)
    dense, eng, store = make_engine_pair(CachedStore, mesh=mesh)
    ids = zipf_stream(24, exponent=1.05)
    want = dense.predict(ids)
    eng.submit_many(list(ids))
    np.testing.assert_allclose(eng.serve_pending(), want, rtol=0, atol=1e-2)
    eng.refresh_cache()
    np.testing.assert_allclose(eng.predict(ids), want, rtol=0, atol=1e-2)
    assert eng.stats.cache_misses == 1            # refresh never recompiled
    key = eng.model.main_embedding_key
    for leaf in ("cache_scale", "backing_scale", "slot_of_row"):
        spec_t = tuple(eng.params[key][leaf].sharding.spec)
        assert all(ax is None for ax in spec_t), (leaf, spec_t)


@needs(8)
def test_quantized_partition_spec_replicates_scales():
    store = CachedStore(SPEC, capacity=32, row_dtype="int8")
    ps = store.partition_spec("model")
    assert {"cache_scale", "backing_scale"} <= set(ps)
    assert tuple(ps["cache_scale"]) == () == tuple(ps["backing_scale"])
