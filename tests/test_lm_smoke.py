"""Per-assigned-architecture smoke tests (deliverable f).

Each arch instantiates a REDUCED same-family config (LMConfig.reduced —
small width/depth/experts/vocab) and runs one forward + one train step on
CPU asserting output shapes and no NaNs. The FULL configs are exercised via
the dry-run only (ShapeDtypeStruct, no allocation).
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.models.lm import make_lm_model
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update

B, S = 2, 16


def _batch(cfg):
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, S, cfg.d_model),
            dtype=jnp.dtype(cfg.dtype)) * 0.1
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, 4, cfg.d_model),
            dtype=jnp.dtype(cfg.dtype)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = make_lm_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    # forward: shape + finiteness
    if cfg.family == "encdec":
        logits = model.forward(params, batch["tokens"], batch["frames"])
        assert logits.shape == (B, S, cfg.vocab)
    elif cfg.family == "vlm":
        logits = model.forward(params, batch["tokens"],
                               batch["patch_embeds"])
        assert logits.shape == (B, S + 4, cfg.vocab)
    else:
        logits = model.forward(params, batch["tokens"])
        assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    # one train step: loss finite, params update, no NaNs
    opt = AdamWConfig(lr=1e-3)
    state = adamw_init(params, opt)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    state, metrics = adamw_update(state, grads, opt)
    assert np.isfinite(float(metrics["grad_norm"]))
    for leaf in jax.tree.leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ["llama3-8b", "qwen3-4b",
                                  "phi3.5-moe-42b-a6.6b", "rwkv6-7b",
                                  "zamba2-1.2b", "whisper-small",
                                  "pixtral-12b"])
def test_reduced_decode_matches_forward(arch):
    """prefill + one decode step == teacher-forced forward (last position)."""
    cfg = get_config(arch).reduced()
    if cfg.family == "moe":
        import dataclasses
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    model = make_lm_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    toks = batch["tokens"]

    if cfg.family == "encdec":
        cache = model.init_cache(B, S + 4, S)
        lp, cache = model.prefill(params, toks, batch["frames"], cache)
        full = lambda t: model.forward(params, t, batch["frames"])
    elif cfg.family == "vlm":
        cache = model.init_cache(B, 4 + S + 4)
        lp, cache = model.prefill(params, toks, cache,
                                  patch_embeds=batch["patch_embeds"])
        full = lambda t: model.forward(params, t, batch["patch_embeds"])
    elif cfg.family == "ssm":
        cache = model.init_cache(B, 0)
        lp, cache = model.prefill(params, toks, cache)
        full = lambda t: model.forward(params, t)
    else:
        cache = model.init_cache(B, S + 4)
        lp, cache = model.prefill(params, toks, cache)
        full = lambda t: model.forward(params, t)

    nxt = jnp.argmax(lp, -1)[:, None].astype(toks.dtype)
    ld, cache = model.decode_step(params, nxt, cache)
    ref = full(jnp.concatenate([toks, nxt], axis=1))[:, -1]
    np.testing.assert_allclose(np.asarray(ld, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)
