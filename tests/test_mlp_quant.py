"""Quantized compute (compute_dtype="int8") through plans and serving.

The dense-branch half of the quantization story: per-output-channel int8
weights baked at plan compile, per-row int8 activations, fused
dequant+bias+ReLU — scored against the fp32 plan and exercised through
the engine with the full int8 stack (rows + matmuls) under refresh.
"""

import numpy as np
import pytest
import jax

from repro.configs import ctr_spec
from repro.core import COMPUTE_DTYPES, compile_plan, plan_key_for
from repro.data.synthetic import CRITEO, synthetic_batch
from repro.models.ctr import CTR_MODELS

VOCAB = 2_000
BATCH = 16


def _setup(model_name, hidden=64):
    spec = ctr_spec(model_name, "criteo", 8, hidden, max_field=VOCAB)
    model = CTR_MODELS[model_name](spec)
    params = model.init(jax.random.PRNGKey(0))
    ids = synthetic_batch(CRITEO.scaled(VOCAB), 5, BATCH)["ids"]
    return model, params, ids


def test_compute_dtype_is_plan_identity():
    model, params, _ = _setup("dcn")
    k32 = plan_key_for(model, "dual", BATCH)
    k8 = plan_key_for(model, "dual", BATCH, compute_dtype="int8")
    assert k32 != k8
    assert k32.compute_dtype == "fp32" and k8.compute_dtype == "int8"
    assert set(COMPUTE_DTYPES) == {"fp32", "int8"}


def test_compile_plan_rejects_unknown_dtype():
    model, params, _ = _setup("dcn")
    with pytest.raises(ValueError, match="compute_dtype"):
        compile_plan(model, params, "dual", BATCH, compute_dtype="int4")


@pytest.mark.parametrize("model_name", list(CTR_MODELS))
def test_int8_plan_scores_close_to_fp32(model_name):
    model, params, ids = _setup(model_name)
    p32 = compile_plan(model, params, "dual", BATCH)
    p8 = compile_plan(model, params, "dual", BATCH, compute_dtype="int8")
    assert p32.key != p8.key
    s32 = np.asarray(p32(ids)).reshape(-1)
    s8 = np.asarray(p8(ids)).reshape(-1)
    # logit-level budget on untrained params; the trained, score-level
    # gate is benchmarks/accuracy_parity --quant-mlp
    assert float(np.abs(s32 - s8).max()) < 1e-2


def test_int8_plan_stats_counters():
    model, params, _ = _setup("widedeep", hidden=64)
    p8 = compile_plan(model, params, "dual", BATCH, compute_dtype="int8")
    st = p8.stats
    assert st.compute_dtype == "int8"
    assert st.mlp_quant_matmuls == 3              # (64,)*3 deep branch
    # int8 payload + 4B/channel scales vs 4B/elem fp32: >= 3.5x smaller
    fp32_bytes = st.mlp_quant_weight_bytes + st.mlp_quant_weight_bytes_saved
    assert st.mlp_quant_weight_bytes > 0
    assert fp32_bytes / st.mlp_quant_weight_bytes >= 3.5

    p32 = compile_plan(model, params, "dual", BATCH)
    assert p32.stats.compute_dtype == "fp32"
    assert p32.stats.mlp_quant_matmuls == 0
    assert p32.stats.mlp_quant_weight_bytes == 0


def test_engine_int8_stack_refresh_is_recompile_free():
    """int8 rows + int8 matmuls served together: a mid-stream refresh is
    a tensor swap — plan cache intact, counters flowing."""
    from repro.embedding import CachedStore
    from repro.serving import FixedBatch, InferenceEngine

    model, params, _ = _setup("dcn")
    store = CachedStore(model.spec.embedding_spec(), capacity=256,
                        row_dtype="int8")
    eng = InferenceEngine(model, params, policy=FixedBatch(BATCH),
                          store=store, compute_dtype="int8")
    ids = synthetic_batch(CRITEO.scaled(VOCAB), 9, BATCH * 4)["ids"]
    waves = np.array_split(np.asarray(ids), 2)

    eng.submit_many(list(waves[0]))
    first = eng.serve_pending()
    misses = eng.stats.cache_misses
    assert misses >= 1
    eng.refresh_cache()
    eng.submit_many(list(waves[1]))
    second = np.concatenate([eng.serve_pending(), eng.flush()])
    assert eng.stats.cache_misses == misses       # zero recompiles
    assert eng.stats.emb_cache_refreshes == 1
    assert first.size + second.size == BATCH * 4

    s = eng.stats
    # 3 q8 matmuls per executed batch, mirrored weight-byte counters
    assert s.mlp_quant_matmuls == 3 * s.n_batches
    assert s.mlp_quant_weight_bytes > 0
    assert s.mlp_quant_weight_bytes_saved > 3.5 * 0  # present and positive
    assert (s.mlp_quant_weight_bytes + s.mlp_quant_weight_bytes_saved
            ) / s.mlp_quant_weight_bytes >= 3.5


def test_runtime_aggregates_mlp_quant_counters():
    from repro.serving import FixedBatch, ServingRuntime

    rt = ServingRuntime()
    for name in ("dcn", "deepfm"):
        model, params, _ = _setup(name)
        rt.add_model(name, model, params, policy=FixedBatch(BATCH),
                     compute_dtype="int8")
    ids = synthetic_batch(CRITEO.scaled(VOCAB), 13, BATCH)["ids"]
    for name in ("dcn", "deepfm"):
        rt.submit_many(name, list(np.asarray(ids)))
        rt.engine(name).serve_pending()
    agg = rt.stats()
    per = [rt.engine(n).stats for n in ("dcn", "deepfm")]
    assert agg.mlp_quant_matmuls == sum(s.mlp_quant_matmuls for s in per) > 0
    assert agg.mlp_quant_weight_bytes == sum(s.mlp_quant_weight_bytes
                                             for s in per) > 0
