"""Multi-chip serving tests on simulated host-device meshes.

These parameterize over real >1-device meshes (2x1 and 4x2), so they skip
on a plain single-device run; the CI ``tier1-mesh`` job forces
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so every one of
them runs on every PR. Core acceptance: zipf traffic through a
``CachedStore`` engine on a mesh stays (tight-tolerance) equal to the
dense 1-device baseline, ``refresh_cache()`` keeps scores **bit-exact**
across the swap with **zero plan recompiles**, and the published tensors
carry the plans' shardings (backing row-sharded over model, cache +
``slot_of_row`` replicated, batches over data).
"""

import numpy as np
import pytest
import jax
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh
from repro.configs import ctr_spec
from repro.core import compile_plan
from repro.data.synthetic import CRITEO, zipf_ids
from repro.embedding import CachedStore
from repro.serving import BucketedBatch, InferenceEngine, ServingRuntime

SCHEMA = CRITEO.scaled(2_000)
SPEC_KW = dict(embed_dim=8, hidden=64, max_field=2_000)


def needs(n):
    return pytest.mark.skipif(
        jax.device_count() < n,
        reason=f"needs {n} devices (run under XLA_FLAGS="
               "--xla_force_host_platform_device_count=8)")


def make(model_name="widedeep"):
    from repro.models.ctr import CTR_MODELS
    spec = ctr_spec(model_name, "criteo", **SPEC_KW)
    model = CTR_MODELS[model_name](spec)
    params = model.init(jax.random.PRNGKey(0))
    return spec, model, params


def zipf_stream(n, seed=0, exponent=1.1):
    return np.asarray(zipf_ids(jax.random.PRNGKey(seed), n,
                               SCHEMA.field_sizes, exponent=exponent))


def serve_waves(eng, ids, waves=4):
    out = []
    for wave in np.array_split(ids, waves):
        eng.submit_many(list(wave))
        out.append(eng.serve_pending())
    out.append(eng.flush())
    return np.concatenate(out)


# --- the multi-chip refresh acceptance (ISSUE-5 satellite) -------------------

@pytest.mark.parametrize("shape", [pytest.param((2, 1), marks=needs(2)),
                                   pytest.param((4, 2), marks=needs(8))])
def test_mesh_refresh_bitexact_vs_dense_baseline(shape):
    """Zipf traffic on a 2x1 / 4x2 mesh: CachedStore engine matches the
    dense 1-device baseline, refresh keeps scores bit-exact, and the plan
    cache reports zero recompiles across every refresh."""
    spec, model, params = make()
    ids = zipf_stream(96)
    _, base_model, base_params = make()
    base = InferenceEngine(base_model, base_params,
                           policy=BucketedBatch((8, 16)))
    want = serve_waves(base, ids)

    mesh = make_mesh(shape, ("data", "model"))
    store = CachedStore(spec.embedding_spec(), capacity=128)
    eng = InferenceEngine(model, params, mesh=mesh, store=store,
                          policy=BucketedBatch((8, 16)), refresh_every=2)
    eng.warmup()
    compiles = eng.stats.cache_misses
    plans = set(eng.cached_plans)

    got = serve_waves(eng, ids)          # auto-refreshes fire mid-stream
    # sharded scores == 1-device baseline (XLA partitioning may differ by
    # float ulps; the store swap itself is bit-exact by construction)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    pre = eng.predict(ids[:16])
    eng.refresh_cache()
    post = eng.predict(ids[:16])
    np.testing.assert_array_equal(pre, post)      # bit-exact across swap
    assert eng.stats.emb_cache_refreshes > 0
    assert eng.stats.cache_misses == compiles     # zero recompiles
    assert set(eng.cached_plans) == plans


@pytest.mark.parametrize("shape", [pytest.param((2, 1), marks=needs(2)),
                                   pytest.param((4, 2), marks=needs(8))])
def test_mesh_refresh_publishes_placed_tensors(shape):
    """The double-buffered swap must publish tensors already placed to
    the plans' shardings — backing row-sharded over model (when the axis
    is >1), cache and slot_of_row replicated — not unplaced host arrays."""
    spec, model, params = make()
    mesh = make_mesh(shape, ("data", "model"))
    store = CachedStore(spec.embedding_spec(), capacity=64)
    eng = InferenceEngine(model, params, mesh=mesh, store=store,
                          policy=BucketedBatch((8,)))
    eng.predict(zipf_stream(32))
    eng.refresh_cache()
    sub = eng.params[eng.model.main_embedding_key]
    plan = eng.plan_for(8)
    for leaf in ("backing", "cache", "slot_of_row"):
        sh = sub[leaf].sharding
        assert isinstance(sh, jax.sharding.NamedSharding), (leaf, sh)
        assert sh.mesh.shape == mesh.shape, leaf
        # published placement must match what the plans were lowered
        # against — the refresh re-derivation (EmbeddingStore.place) and
        # the recorded plan contract may never drift apart
        recorded = plan.runtime_shardings[f"emb:{leaf}"]
        assert sh.is_equivalent_to(recorded, sub[leaf].ndim), (
            leaf, sh, recorded)
    backing_dims = tuple(sub["backing"].sharding.spec)
    if shape[1] > 1:
        assert backing_dims[0] == "model", backing_dims
    assert all(a is None for a in tuple(sub["cache"].sharding.spec))


# --- resolved plan shardings -------------------------------------------------

@needs(8)
def test_plan_input_shardings_batch_over_data_axis():
    _, model, params = make()
    mesh = make_mesh((4, 2), ("data", "model"))
    plan = compile_plan(model, params, "dual", 16, mesh=mesh)
    assert plan.mesh is mesh
    assert tuple(plan.input_shardings["ids"].spec) == ("data", None)


@needs(8)
def test_plan_input_shardings_odd_batch_falls_back_to_replication():
    """A batch size the data axis doesn't divide must compile (fit_spec
    drops the axis) and still serve correctly."""
    _, model, params = make()
    ids = zipf_stream(6)
    want = compile_plan(model, params, "dual", 6).predict(ids)
    mesh = make_mesh((4, 2), ("data", "model"))
    plan = compile_plan(model, params, "dual", 6, mesh=mesh)
    assert tuple(plan.input_shardings["ids"].spec)[0] is None
    np.testing.assert_allclose(plan.predict(ids), want,
                               rtol=1e-5, atol=1e-6)


@needs(8)
def test_plan_runtime_shardings_follow_store_partition_spec():
    spec, model, params = make()
    store = CachedStore(spec.embedding_spec(), capacity=64)
    params = model.use_store(store, params)
    mesh = make_mesh((4, 2), ("data", "model"))
    plan = compile_plan(model, params, "dual", 8, mesh=mesh)
    rt = plan.runtime_shardings
    assert tuple(rt["emb:backing"].spec) == ("model", None)
    assert rt["emb:cache"].is_fully_replicated
    assert rt["emb:slot_of_row"].is_fully_replicated
    assert not rt["emb:backing"].is_fully_replicated
    assert set(plan.runtime_inputs) == set(rt)


@needs(8)
def test_data_only_mesh_replicates_tables_and_shards_batches():
    """--mesh data=N style: no model axis — tables replicate, batches
    still shard over data."""
    _, model, params = make()
    ids = zipf_stream(16)
    want = compile_plan(model, params, "dual", 16).predict(ids)
    mesh = make_mesh((8,), ("data",))
    plan = compile_plan(model, params, "dual", 16, mesh=mesh)
    assert tuple(plan.input_shardings["ids"].spec) == ("data", None)
    np.testing.assert_allclose(plan.predict(ids), want,
                               rtol=1e-5, atol=1e-6)


@needs(8)
def test_eager_levels_serve_on_mesh():
    """The non-AOT levels dispatch op-by-op over placed params; they must
    agree with the unsharded plan too."""
    _, model, params = make()
    ids = zipf_stream(8)
    want = compile_plan(model, params, "dual", 8).predict(ids)
    mesh = make_mesh((4, 2), ("data", "model"))
    for level in ("fused_emb", "fused_all"):
        got = compile_plan(model, params, level, 8, mesh=mesh).predict(ids)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6,
                                   err_msg=level)


# --- runtime-level mesh serving ----------------------------------------------

@needs(8)
def test_serving_runtime_shares_mesh_and_refreshes_placed():
    """ServingRuntime(mesh=...) hands the mesh to every hosted engine and
    its shared-admission refresh_all republishes placed tensors."""
    spec, m1, p1 = make("widedeep")
    _, m2, p2 = make("dcn")
    mesh = make_mesh((4, 2), ("data", "model"))
    rt = ServingRuntime(mesh=mesh)
    rt.add_model("widedeep", m1, p1, policy=BucketedBatch((8,)),
                 store=CachedStore(spec.embedding_spec(), capacity=64))
    rt.add_model("dcn", m2, p2, policy=BucketedBatch((8,)))
    assert rt.engine("widedeep").mesh is mesh
    assert rt.engine("dcn").mesh is mesh

    ids = zipf_stream(32)
    pre = rt.predict("widedeep", ids)
    assert rt.refresh_all() == 1
    post = rt.predict("widedeep", ids)
    np.testing.assert_array_equal(pre, post)
    sub = rt.engine("widedeep").params["emb"]
    assert tuple(sub["backing"].sharding.spec) == ("model", None)

    _, base_model, base_params = make("dcn")
    base = InferenceEngine(base_model, base_params,
                           policy=BucketedBatch((8,)))
    np.testing.assert_allclose(rt.predict("dcn", ids), base.predict(ids),
                               rtol=1e-5, atol=1e-6)
