"""Hypothesis property tests on the system's invariants.

Invariants:
  P1  Alg.-1 fused lookup == per-field serial lookup, any shapes/ids.
  P2  breadth-first queue is a permutation of both branches, interleaves
      them maximally, and the longer branch launches first (Alg. 2).
  P3  fuse_non_gemm preserves graph semantics for random elementwise DAGs.
  P4  checkpoint save→restore is the identity for arbitrary pytrees.
  P5  online-softmax (flash) attention == direct attention.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (FusedEmbeddingCollection, FusedEmbeddingSpec, Op,
                        OpGraph, breadth_first_schedule, fuse_non_gemm)
from repro.kernels import ref
from repro.models.lm import layers as L

SETTINGS = dict(max_examples=25, deadline=None)


# --- P1 -----------------------------------------------------------------

@settings(**SETTINGS)
@given(st.data())
def test_fused_lookup_equals_serial(data):
    k = data.draw(st.integers(1, 8), label="k")
    d = data.draw(st.sampled_from([1, 4, 8, 16]), label="d")
    b = data.draw(st.integers(1, 17), label="b")
    sizes = data.draw(st.lists(st.integers(1, 40), min_size=k, max_size=k))
    rng = np.random.default_rng(0)
    spec = FusedEmbeddingSpec(field_sizes=tuple(sizes), dim=d)
    emb = FusedEmbeddingCollection(spec)
    params = emb.init(jax.random.PRNGKey(0))
    ids = jnp.asarray(
        np.stack([rng.integers(0, n, size=b) for n in sizes], axis=1),
        dtype=jnp.int32)
    fused = emb.apply(params, ids, strategy="jnp")
    serial = emb.apply_serial(params, ids)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(serial),
                               rtol=1e-6, atol=1e-6)


# --- P2 -----------------------------------------------------------------

def _ops(prefix, n, module):
    return [Op(f"{prefix}{i}", lambda x: x, ("in",), f"{prefix}o{i}",
               module=module) for i in range(n)]


@settings(**SETTINGS)
@given(ne=st.integers(0, 12), ni=st.integers(0, 12))
def test_breadth_first_schedule_properties(ne, ni):
    explicit = _ops("e", ne, "explicit")
    implicit = _ops("i", ni, "implicit")
    sched = breadth_first_schedule(explicit, implicit)
    q = sched.queue
    assert sorted(q) == sorted([o.name for o in explicit + implicit])
    if ne and ni:
        # maximal interleave: first 2*min(ne,ni) slots alternate branches
        for j in range(min(ne, ni)):
            pair = {q[2 * j][0], q[2 * j + 1][0]}
            assert pair == {"e", "i"}
        # Alg. 2: the module with more operators launches first
        longer = "i" if ni > ne else "e"
        assert q[0][0] == longer
    # intra-branch order is preserved (valid topological restriction)
    for pfx in ("e", "i"):
        idx = [int(n[1:]) for n in q if n.startswith(pfx)]
        assert idx == sorted(idx)


# --- P3 -----------------------------------------------------------------

@settings(**SETTINGS)
@given(st.data())
def test_fusion_preserves_semantics(data):
    n = data.draw(st.integers(2, 10), label="n_ops")
    rng = np.random.default_rng(data.draw(st.integers(0, 1000)))
    g = OpGraph(["in"])
    edges = ["in"]
    fns = [lambda x: x + 1.0, lambda x: x * 2.0, jnp.tanh,
           lambda x: jnp.maximum(x, 0.0)]
    for i in range(n):
        src = edges[rng.integers(0, len(edges))]
        is_gemm = bool(rng.random() < 0.3)
        fn = (lambda x: x @ np.eye(4, dtype=np.float32) * 0.5) if is_gemm \
            else fns[rng.integers(0, len(fns))]
        g.add(Op(f"op{i}", fn, (src,), f"v{i}", is_gemm=is_gemm,
                 module="explicit"))
        edges.append(f"v{i}")
    x = jnp.asarray(rng.normal(size=(3, 4)), dtype=jnp.float32)
    env_plain = g.execute({"in": x})
    fused = fuse_non_gemm(g)
    env_fused = fused.execute({"in": x})
    # every edge still visible after fusion must agree
    for key, val in env_fused.items():
        np.testing.assert_allclose(np.asarray(val),
                                   np.asarray(env_plain[key]),
                                   rtol=1e-6, atol=1e-6)
    assert fused.n_kernels() <= g.n_kernels()


# --- P4 -----------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(data=st.data())
def test_checkpoint_roundtrip(tmp_path_factory, data):
    from repro.training import restore_checkpoint, save_checkpoint
    rng = np.random.default_rng(data.draw(st.integers(0, 100)))
    depth = data.draw(st.integers(1, 3))

    def tree(d):
        if d == 0:
            return jnp.asarray(rng.normal(size=tuple(
                rng.integers(1, 5, size=rng.integers(1, 3)))),
                dtype=jnp.float32)
        return {f"k{i}": tree(d - 1) for i in range(rng.integers(1, 3))}

    t = tree(depth)
    path = tmp_path_factory.mktemp("ckpt")
    save_checkpoint(str(path), 1, t)
    back = restore_checkpoint(str(path), 1, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --- P5 -----------------------------------------------------------------

@settings(**SETTINGS)
@given(st.data())
def test_flash_equals_direct_attention(data):
    b = data.draw(st.integers(1, 3))
    s = data.draw(st.sampled_from([8, 16, 32]))
    h = data.draw(st.sampled_from([2, 4]))
    kv = data.draw(st.sampled_from([1, 2]))
    hd = data.draw(st.sampled_from([4, 8]))
    causal = data.draw(st.booleans())
    chunk = data.draw(st.sampled_from([4, 8, s]))
    key = jax.random.PRNGKey(data.draw(st.integers(0, 1000)))
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    direct = L._sdpa(q, k, v, causal=causal)
    flash = L.flash_attention(q, k, v, causal=causal,
                              q_chunk=chunk, k_chunk=chunk)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(flash),
                               rtol=2e-5, atol=2e-5)


# --- P6 -----------------------------------------------------------------

from repro import quant  # noqa: E402


@settings(**SETTINGS)
@given(st.data())
def test_per_channel_quant_round_trip(data):
    """Per-output-channel absmax quantization (the compute_dtype="int8"
    weight format): round-trip error is bounded by half the grid step of
    each column, -128 is never emitted (symmetric grid), and all-zero
    columns hit the SCALE_EPS floor so they round-trip to exact zero."""
    fan_in = data.draw(st.integers(1, 48), label="fan_in")
    fan_out = data.draw(st.integers(1, 48), label="fan_out")
    seed = data.draw(st.integers(0, 1000), label="seed")
    rng = np.random.default_rng(seed)
    w = rng.normal(scale=data.draw(st.sampled_from([1e-3, 1.0, 50.0])),
                   size=(fan_in, fan_out)).astype(np.float32)
    if fan_out > 1 and data.draw(st.booleans(), label="zero_col"):
        w[:, rng.integers(0, fan_out)] = 0.0

    q, scale = quant.quantize_channels(jnp.asarray(w))
    q, scale = np.asarray(q), np.asarray(scale)
    assert q.dtype == np.int8 and q.shape == w.shape
    assert scale.shape == (1, fan_out)
    assert q.min(initial=0) >= -127                    # -128 never emitted

    back = np.asarray(quant.dequantize_channels(jnp.asarray(q),
                                                jnp.asarray(scale)))
    assert np.all(np.abs(back - w) <= scale * 0.5 + 1e-7)

    zero_cols = np.all(w == 0.0, axis=0)
    if zero_cols.any():
        assert np.all(scale[0, zero_cols] == np.float32(quant.SCALE_EPS))
        assert np.all(q[:, zero_cols] == 0)
        assert np.all(back[:, zero_cols] == 0.0)
