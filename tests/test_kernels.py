"""Per-kernel allclose validation against the pure-jnp oracles.

Sweeps shapes and dtypes per the deliverable: every Pallas kernel is executed
in interpret mode (CPU) and compared against repro.kernels.ref.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.multi_table_lookup import (
    mtl_gather,
    mtl_gather_multihot,
    mtl_input_first,
    mtl_onehot,
)

TOL = dict(rtol=1e-5, atol=1e-5)
BF16_TOL = dict(rtol=2e-2, atol=2e-2)


def make_tables(rng, sizes, d, dtype):
    tables = [jnp.asarray(rng.normal(size=(n, d)), dtype=dtype) for n in sizes]
    mega = jnp.concatenate(tables, axis=0)
    offsets = jnp.asarray(np.concatenate([[0], np.cumsum(sizes)[:-1]]),
                          dtype=jnp.int32)
    return tables, mega, offsets


def make_ids(rng, sizes, b):
    return jnp.asarray(
        np.stack([rng.integers(0, n, size=b) for n in sizes], axis=1),
        dtype=jnp.int32)


# ---------------------------------------------------------------------------
# Algorithm 1 anchoring: literal paper pseudocode == vectorized oracle
# ---------------------------------------------------------------------------

def test_alg1_literal_matches_vectorized():
    rng = np.random.default_rng(0)
    sizes, d, b = [3, 17, 5], 4, 6
    tables, mega, offsets = make_tables(rng, sizes, d, jnp.float32)
    ids = make_ids(rng, sizes, b)
    lit = ref.multi_table_lookup_alg1(np.asarray(ids),
                                      [np.asarray(t) for t in tables])
    vec = ref.ref_multi_table_lookup(ids, mega, offsets, len(sizes))
    np.testing.assert_allclose(lit, vec, **TOL)


# ---------------------------------------------------------------------------
# mtl_gather (output-first, the paper's kernel) — shape × dtype sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d", [8, 16, 32, 128])
@pytest.mark.parametrize("b,k", [(4, 2), (16, 5), (32, 9)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mtl_gather_sweep(b, k, d, dtype):
    rng = np.random.default_rng(b * k * d)
    sizes = list(rng.integers(2, 50, size=k))
    _, mega, offsets = make_tables(rng, sizes, d, dtype)
    ids = make_ids(rng, sizes, b)
    want = ref.ref_multi_table_lookup(ids, mega, offsets, k)
    rows = (ids + offsets[None, :]).reshape(-1)
    got = mtl_gather(rows, mega, interpret=True).reshape(b, k * d)
    tol = BF16_TOL if dtype == jnp.bfloat16 else TOL
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


@pytest.mark.parametrize("strategy", ["jnp", "pallas", "serial", "input_first"])
def test_ops_dispatch_equivalence(strategy):
    rng = np.random.default_rng(7)
    sizes, d, b = [11, 3, 40, 8], 16, 24
    _, mega, offsets = make_tables(rng, sizes, d, jnp.float32)
    ids = make_ids(rng, sizes, b)
    want = ref.ref_multi_table_lookup(ids, mega, offsets, len(sizes))
    got = ops.multi_table_lookup(ids, mega, offsets, strategy=strategy,
                                 interpret=True)
    np.testing.assert_allclose(got, want, **TOL)


def test_input_first_matches_output_first():
    """Fig.-11 pair must be numerically identical (only layout differs)."""
    rng = np.random.default_rng(3)
    sizes, d, b = [9, 21], 8, 10
    _, mega, offsets = make_tables(rng, sizes, d, jnp.float32)
    ids = make_ids(rng, sizes, b)
    a = ops.multi_table_lookup(ids, mega, offsets, strategy="pallas",
                               interpret=True)
    z = ops.multi_table_lookup(ids, mega, offsets, strategy="input_first",
                               interpret=True)
    np.testing.assert_allclose(a, z, **TOL)


# ---------------------------------------------------------------------------
# one-hot MXU variant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d", [8, 32])
@pytest.mark.parametrize("n_pad", [16, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mtl_onehot_sweep(d, n_pad, dtype):
    rng = np.random.default_rng(d + n_pad)
    k, b = 4, 20
    stacked = jnp.asarray(rng.normal(size=(k, n_pad, d)), dtype=dtype)
    ids = jnp.asarray(rng.integers(0, n_pad, size=(b, k)), dtype=jnp.int32)
    got = mtl_onehot(ids, stacked, interpret=True)
    want = jnp.stack([stacked[f][ids[:, f]] for f in range(k)], axis=1)
    tol = BF16_TOL if dtype == jnp.bfloat16 else TOL
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


# ---------------------------------------------------------------------------
# multi-hot pooling
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("h", [1, 3, 5])
def test_multihot(h):
    rng = np.random.default_rng(h)
    sizes, d, b = [13, 29, 6], 16, 12
    k = len(sizes)
    _, mega, offsets = make_tables(rng, sizes, d, jnp.float32)
    mega_z = jnp.concatenate([mega, jnp.zeros((1, d), jnp.float32)], axis=0)
    ids = jnp.asarray(
        np.stack([rng.integers(0, n, size=(b, h)) for n in sizes], axis=1),
        dtype=jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, size=(b, k, h)), dtype=jnp.float32)
    want = ref.ref_multi_hot_lookup(ids, mask, mega_z, offsets)
    got = ops.multi_table_lookup_multihot(ids, mask, mega_z, offsets,
                                          strategy="pallas", interpret=True)
    np.testing.assert_allclose(got, want, **TOL)


# ---------------------------------------------------------------------------
# two-level (cache + backing) gather
# ---------------------------------------------------------------------------

def _split_cache(rng, mega, capacity):
    """Random hot set of ``capacity`` rows + its slot map."""
    n = mega.shape[0]
    hot = np.sort(rng.choice(n, size=capacity, replace=False))
    slot_of_row = np.full(n, -1, dtype=np.int32)
    slot_of_row[hot] = np.arange(capacity, dtype=np.int32)
    cache = jnp.take(mega, jnp.asarray(hot), axis=0)
    return cache, jnp.asarray(slot_of_row)


@pytest.mark.parametrize("capacity", [1, 16, 48])
def test_two_level_gather_matches_dense(capacity):
    rng = np.random.default_rng(capacity)
    sizes, d, b = [13, 29, 6], 16, 24
    _, mega, offsets = make_tables(rng, sizes, d, jnp.float32)
    cache, slot_of_row = _split_cache(rng, mega, capacity)
    ids = jnp.asarray(
        np.stack([rng.integers(0, n, size=b) for n in sizes], axis=1),
        dtype=jnp.int32)
    want = ops.multi_table_lookup(ids, mega, offsets, strategy="jnp")
    got = ops.multi_table_lookup_cached(ids, cache, mega, slot_of_row,
                                        offsets, strategy="jnp")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    got_pl = ops.multi_table_lookup_cached(ids, cache, mega, slot_of_row,
                                           offsets, strategy="pallas",
                                           interpret=True)
    np.testing.assert_array_equal(np.asarray(got_pl), np.asarray(want))


@pytest.mark.parametrize("h", [1, 3])
def test_two_level_multihot_matches_dense(h):
    rng = np.random.default_rng(h)
    sizes, d, b = [13, 29, 6], 16, 12
    k = len(sizes)
    _, mega, offsets = make_tables(rng, sizes, d, jnp.float32)
    mega_z = jnp.concatenate([mega, jnp.zeros((1, d), jnp.float32)], axis=0)
    cache, slot_of_row = _split_cache(rng, mega_z, 16)
    ids = jnp.asarray(
        np.stack([rng.integers(0, n, size=(b, h)) for n in sizes], axis=1),
        dtype=jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, size=(b, k, h)), dtype=jnp.float32)
    want_jnp = ops.multi_table_lookup_multihot(ids, mask, mega_z, offsets,
                                               strategy="jnp")
    got_jnp = ops.multi_table_lookup_cached_multihot(
        ids, mask, cache, mega_z, slot_of_row, offsets, strategy="jnp")
    np.testing.assert_array_equal(np.asarray(got_jnp), np.asarray(want_jnp))
    want_pl = ops.multi_table_lookup_multihot(ids, mask, mega_z, offsets,
                                              strategy="pallas",
                                              interpret=True)
    got_pl = ops.multi_table_lookup_cached_multihot(
        ids, mask, cache, mega_z, slot_of_row, offsets, strategy="pallas",
        interpret=True)
    np.testing.assert_array_equal(np.asarray(got_pl), np.asarray(want_pl))


# ---------------------------------------------------------------------------
# three-level (cache / staging / zero-guard) gather
# ---------------------------------------------------------------------------

def _split_tiers(rng, mega, capacity, staged):
    """Disjoint cache + staging over ``mega``'s rows, plus both slot maps
    (rows in neither tier keep -1 in both — the zero-guard case)."""
    n = mega.shape[0]
    pick = rng.choice(n, size=capacity + staged, replace=False)
    hot, warm = np.sort(pick[:capacity]), np.sort(pick[capacity:])
    slot_of_row = np.full(n, -1, dtype=np.int32)
    slot_of_row[hot] = np.arange(capacity, dtype=np.int32)
    smap = np.full(n, -1, dtype=np.int32)
    smap[warm] = np.arange(staged, dtype=np.int32)
    cache = jnp.take(mega, jnp.asarray(hot), axis=0)
    staging = jnp.take(mega, jnp.asarray(warm), axis=0)
    return cache, staging, jnp.asarray(slot_of_row), jnp.asarray(smap)


@pytest.mark.parametrize("capacity", [1, 16, 40])
def test_three_level_gather_matches_dense_when_fully_staged(capacity):
    """Every row in some tier -> bitwise equal to the dense gather (the
    HostBackedStore contract: the serve path stages all misses first)."""
    rng = np.random.default_rng(capacity)
    sizes, d, b = [13, 29, 6], 16, 24
    _, mega, offsets = make_tables(rng, sizes, d, jnp.float32)
    cache, staging, slot_of_row, smap = _split_tiers(
        rng, mega, capacity, mega.shape[0] - capacity)   # all rows covered
    ids = jnp.asarray(
        np.stack([rng.integers(0, n, size=b) for n in sizes], axis=1),
        dtype=jnp.int32)
    want = ops.multi_table_lookup(ids, mega, offsets, strategy="jnp")
    got = ops.multi_table_lookup_host(ids, cache, staging, slot_of_row,
                                      smap, offsets, strategy="jnp")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    got_pl = ops.multi_table_lookup_host(ids, cache, staging, slot_of_row,
                                         smap, offsets, strategy="pallas",
                                         interpret=True)
    np.testing.assert_array_equal(np.asarray(got_pl), np.asarray(want))


def test_three_level_gather_zero_guards_unresolved_rows():
    rng = np.random.default_rng(0)
    sizes, d, b = [13, 29, 6], 16, 24
    _, mega, offsets = make_tables(rng, sizes, d, jnp.float32)
    cache, staging, slot_of_row, smap = _split_tiers(rng, mega, 8, 8)
    ids = jnp.asarray(
        np.stack([rng.integers(0, n, size=b) for n in sizes], axis=1),
        dtype=jnp.int32)
    for strategy in ("jnp", "pallas"):
        got = np.asarray(ops.multi_table_lookup_host(
            ids, cache, staging, slot_of_row, smap, offsets,
            strategy=strategy, interpret=True)).reshape(b, len(sizes), d)
        rows = np.asarray(ids) + np.asarray(offsets)[None, :]
        unresolved = ((np.asarray(slot_of_row)[rows] < 0)
                      & (np.asarray(smap)[rows] < 0))
        assert unresolved.any()
        assert np.all(got[unresolved] == 0.0)
        want = np.asarray(ops.multi_table_lookup(
            ids, mega, offsets, strategy="jnp")).reshape(b, len(sizes), d)
        np.testing.assert_array_equal(got[~unresolved], want[~unresolved])


@pytest.mark.parametrize("h", [1, 3])
def test_three_level_multihot_matches_dense(h):
    rng = np.random.default_rng(h)
    sizes, d, b = [13, 29, 6], 16, 12
    k = len(sizes)
    _, mega, offsets = make_tables(rng, sizes, d, jnp.float32)
    mega_z = jnp.concatenate([mega, jnp.zeros((1, d), jnp.float32)], axis=0)
    cache, staging, slot_of_row, smap = _split_tiers(
        rng, mega_z, 16, mega_z.shape[0] - 16)           # all rows covered
    ids = jnp.asarray(
        np.stack([rng.integers(0, n, size=(b, h)) for n in sizes], axis=1),
        dtype=jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, size=(b, k, h)), dtype=jnp.float32)
    # compare per strategy: jnp and pallas pool in different f32 orders
    for strategy in ("jnp", "pallas"):
        want = ops.multi_table_lookup_multihot(ids, mask, mega_z, offsets,
                                               strategy=strategy,
                                               interpret=True)
        got = ops.multi_table_lookup_host_multihot(
            ids, mask, cache, staging, slot_of_row, smap, offsets,
            strategy=strategy, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# fused non-GEMM kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,D", [(4, 16), (32, 80), (7, 200)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_cross_v2(b, D, dtype):
    rng = np.random.default_rng(b * D)
    x0, xw, x = (jnp.asarray(rng.normal(size=(b, D)), dtype=dtype)
                 for _ in range(3))
    got = ops.fused_cross_v2(x0, xw, x, interpret=True)
    want = ref.ref_cross_v2_elementwise(x0, xw, x)
    tol = BF16_TOL if dtype == jnp.bfloat16 else TOL
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


@pytest.mark.parametrize("b,D", [(4, 16), (32, 80)])
def test_fused_cross_v1(b, D):
    rng = np.random.default_rng(b + D)
    x0 = jnp.asarray(rng.normal(size=(b, D)), dtype=jnp.float32)
    x = jnp.asarray(rng.normal(size=(b, D)), dtype=jnp.float32)
    bias = jnp.asarray(rng.normal(size=(D,)), dtype=jnp.float32)
    xlw = jnp.asarray(rng.normal(size=(b, 1)), dtype=jnp.float32)
    got = ops.fused_cross_v1(x0, xlw, bias, x, interpret=True)
    want = ref.ref_cross_v1_elementwise(x0, xlw, bias, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,k,d", [(4, 3, 8), (32, 13, 16), (16, 39, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_fm(b, k, d, dtype):
    rng = np.random.default_rng(b * k)
    v = jnp.asarray(rng.normal(size=(b, k, d)), dtype=dtype)
    got = ops.fused_fm_second_order(v, interpret=True)[:, 0]
    want = ref.ref_fm_second_order(v.astype(jnp.float32))
    tol = BF16_TOL if dtype == jnp.bfloat16 else TOL
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


# ---------------------------------------------------------------------------
# quantized (int8 rows + per-row fp32 scale) gathers
# ---------------------------------------------------------------------------

from repro import quant  # noqa: E402


def _q8_split_cache(rng, mega, capacity):
    """Quantize the mega-table once, carve a random hot set out of the
    shared int8 grid (cache and backing hold verbatim copies + scales)."""
    q, scale = quant.quantize_rows(mega)
    n = mega.shape[0]
    hot = np.sort(rng.choice(n, size=capacity, replace=False))
    slot_of_row = np.full(n, -1, dtype=np.int32)
    slot_of_row[hot] = np.arange(capacity, dtype=np.int32)
    cache = jnp.take(q, jnp.asarray(hot), axis=0)
    cache_scale = jnp.take(scale, jnp.asarray(hot), axis=0)
    return q, scale, cache, cache_scale, jnp.asarray(slot_of_row)


@pytest.mark.parametrize("capacity", [1, 16, 48])
def test_two_level_q8_round_trip_bound(capacity):
    """Per-element error of the dequantized gather stays within half the
    int8 grid step (scale = absmax/127) of the fp32 dense lookup."""
    rng = np.random.default_rng(capacity)
    sizes, d, b = [13, 29, 6], 16, 24
    _, mega, offsets = make_tables(rng, sizes, d, jnp.float32)
    q, scale, cache, cscale, slot_of_row = _q8_split_cache(
        rng, mega, capacity)
    ids = make_ids(rng, sizes, b)
    want = np.asarray(ops.multi_table_lookup(
        ids, mega, offsets, strategy="jnp")).reshape(b, len(sizes), d)
    got = np.asarray(ops.multi_table_lookup_cached_q8(
        ids, cache, cscale, q, scale, slot_of_row, offsets,
        strategy="jnp")).reshape(b, len(sizes), d)
    rows = np.asarray(ids) + np.asarray(offsets)[None, :]
    bound = np.asarray(scale)[rows] * 0.5 + 1e-7      # (b, k, 1) per row
    assert np.all(np.abs(got - want) <= bound)


@pytest.mark.parametrize("capacity", [1, 16, 48])
def test_two_level_q8_kernel_matches_ref(capacity):
    """The Pallas kernel (interpret mode) is bitwise equal to the jnp ref
    twin — both select the int8 payload + scale, then multiply once."""
    rng = np.random.default_rng(capacity)
    sizes, d, b = [13, 29, 6], 16, 24
    _, mega, offsets = make_tables(rng, sizes, d, jnp.float32)
    q, scale, cache, cscale, slot_of_row = _q8_split_cache(
        rng, mega, capacity)
    ids = make_ids(rng, sizes, b)
    got_jnp = ops.multi_table_lookup_cached_q8(
        ids, cache, cscale, q, scale, slot_of_row, offsets, strategy="jnp")
    got_pl = ops.multi_table_lookup_cached_q8(
        ids, cache, cscale, q, scale, slot_of_row, offsets,
        strategy="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(got_pl), np.asarray(got_jnp))


@pytest.mark.parametrize("h", [1, 3])
def test_two_level_q8_multihot_pooled(h):
    """Pooled multi-hot: fp32 pooling after per-row dequant, masked slots
    hit the zero row (int8 payload 0 -> exact 0.0), and the pooled error
    stays within the sum of the contributing rows' half grid steps."""
    rng = np.random.default_rng(h)
    sizes, d, b = [13, 29, 6], 16, 12
    k = len(sizes)
    _, mega, offsets = make_tables(rng, sizes, d, jnp.float32)
    mega_z = jnp.concatenate([mega, jnp.zeros((1, d), jnp.float32)], axis=0)
    q, scale, cache, cscale, slot_of_row = _q8_split_cache(rng, mega_z, 16)
    ids = jnp.asarray(
        np.stack([rng.integers(0, n, size=(b, h)) for n in sizes], axis=1),
        dtype=jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, size=(b, k, h)), dtype=jnp.float32)
    want = np.asarray(ops.multi_table_lookup_multihot(
        ids, mask, mega_z, offsets, strategy="jnp")).reshape(b, k, d)
    for strategy in ("jnp", "pallas"):
        got = np.asarray(ops.multi_table_lookup_cached_q8_multihot(
            ids, mask, cache, cscale, q, scale, slot_of_row, offsets,
            strategy=strategy, interpret=True)).reshape(b, k, d)
        rows = np.asarray(ids) + np.asarray(offsets)[None, :, None]
        row_scale = np.asarray(scale)[rows][..., 0]    # (b, k, h)
        bound = ((row_scale * 0.5 + 1e-7)
                 * np.asarray(mask)).sum(axis=-1, keepdims=True)
        assert np.all(np.abs(got - want) <= bound + 1e-6)


@pytest.mark.parametrize("capacity", [1, 16, 40])
def test_three_level_q8_staged_round_trip(capacity):
    """Fully staged three-level q8 path: within the per-row grid-step
    bound of the fp32 dense gather, and kernel == ref bitwise."""
    rng = np.random.default_rng(capacity)
    sizes, d, b = [13, 29, 6], 16, 24
    _, mega, offsets = make_tables(rng, sizes, d, jnp.float32)
    q, scale = quant.quantize_rows(mega)
    n = mega.shape[0]
    pick = rng.choice(n, size=n, replace=False)
    hot, warm = np.sort(pick[:capacity]), np.sort(pick[capacity:])
    slot_of_row = np.full(n, -1, dtype=np.int32)
    slot_of_row[hot] = np.arange(capacity, dtype=np.int32)
    smap = np.full(n, -1, dtype=np.int32)
    smap[warm] = np.arange(n - capacity, dtype=np.int32)
    cache = jnp.take(q, jnp.asarray(hot), axis=0)
    cscale = jnp.take(scale, jnp.asarray(hot), axis=0)
    staging = jnp.take(q, jnp.asarray(warm), axis=0)
    sscale = jnp.take(scale, jnp.asarray(warm), axis=0)
    ids = make_ids(rng, sizes, b)
    want = np.asarray(ops.multi_table_lookup(
        ids, mega, offsets, strategy="jnp")).reshape(b, len(sizes), d)
    got_jnp = ops.multi_table_lookup_host_q8(
        ids, cache, cscale, staging, sscale, jnp.asarray(slot_of_row),
        jnp.asarray(smap), offsets, strategy="jnp")
    got_pl = ops.multi_table_lookup_host_q8(
        ids, cache, cscale, staging, sscale, jnp.asarray(slot_of_row),
        jnp.asarray(smap), offsets, strategy="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(got_pl), np.asarray(got_jnp))
    rows = np.asarray(ids) + np.asarray(offsets)[None, :]
    bound = np.asarray(scale)[rows] * 0.5 + 1e-7
    got = np.asarray(got_jnp).reshape(b, len(sizes), d)
    assert np.all(np.abs(got - want) <= bound)


def test_three_level_q8_zero_guards_unresolved_rows():
    rng = np.random.default_rng(0)
    sizes, d, b = [13, 29, 6], 16, 24
    _, mega, offsets = make_tables(rng, sizes, d, jnp.float32)
    q, scale = quant.quantize_rows(mega)
    n = mega.shape[0]
    pick = rng.choice(n, size=16, replace=False)
    hot, warm = np.sort(pick[:8]), np.sort(pick[8:])
    slot_of_row = np.full(n, -1, dtype=np.int32)
    slot_of_row[hot] = np.arange(8, dtype=np.int32)
    smap = np.full(n, -1, dtype=np.int32)
    smap[warm] = np.arange(8, dtype=np.int32)
    cache = jnp.take(q, jnp.asarray(hot), axis=0)
    cscale = jnp.take(scale, jnp.asarray(hot), axis=0)
    staging = jnp.take(q, jnp.asarray(warm), axis=0)
    sscale = jnp.take(scale, jnp.asarray(warm), axis=0)
    ids = make_ids(rng, sizes, b)
    for strategy in ("jnp", "pallas"):
        got = np.asarray(ops.multi_table_lookup_host_q8(
            ids, cache, cscale, staging, sscale, jnp.asarray(slot_of_row),
            jnp.asarray(smap), offsets, strategy=strategy,
            interpret=True)).reshape(b, len(sizes), d)
        rows = np.asarray(ids) + np.asarray(offsets)[None, :]
        unresolved = (slot_of_row[rows] < 0) & (smap[rows] < 0)
        assert unresolved.any()
        assert np.all(got[unresolved] == 0.0)


@pytest.mark.parametrize("h", [1, 3])
def test_three_level_q8_multihot_matches_jnp_twin(h):
    rng = np.random.default_rng(h)
    sizes, d, b = [13, 29, 6], 16, 12
    k = len(sizes)
    _, mega, offsets = make_tables(rng, sizes, d, jnp.float32)
    mega_z = jnp.concatenate([mega, jnp.zeros((1, d), jnp.float32)], axis=0)
    q, scale = quant.quantize_rows(mega_z)
    n = mega_z.shape[0]
    pick = rng.choice(n, size=n, replace=False)
    hot, warm = np.sort(pick[:16]), np.sort(pick[16:])
    slot_of_row = np.full(n, -1, dtype=np.int32)
    slot_of_row[hot] = np.arange(16, dtype=np.int32)
    smap = np.full(n, -1, dtype=np.int32)
    smap[warm] = np.arange(n - 16, dtype=np.int32)
    cache = jnp.take(q, jnp.asarray(hot), axis=0)
    cscale = jnp.take(scale, jnp.asarray(hot), axis=0)
    staging = jnp.take(q, jnp.asarray(warm), axis=0)
    sscale = jnp.take(scale, jnp.asarray(warm), axis=0)
    ids = jnp.asarray(
        np.stack([rng.integers(0, n_, size=(b, h)) for n_ in sizes], axis=1),
        dtype=jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, size=(b, k, h)), dtype=jnp.float32)
    got_jnp = ops.multi_table_lookup_host_q8_multihot(
        ids, mask, cache, cscale, staging, sscale, jnp.asarray(slot_of_row),
        jnp.asarray(smap), offsets, strategy="jnp")
    got_pl = ops.multi_table_lookup_host_q8_multihot(
        ids, mask, cache, cscale, staging, sscale, jnp.asarray(slot_of_row),
        jnp.asarray(smap), offsets, strategy="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(got_pl), np.asarray(got_jnp),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# quantized dense matmul (int8 x int8 -> int32, fused dequant epilogue)
# ---------------------------------------------------------------------------


def _q8_mlp_layer(rng, b, fan_in, fan_out):
    h = jnp.asarray(rng.normal(size=(b, fan_in)), dtype=jnp.float32)
    w = jnp.asarray(rng.normal(size=(fan_in, fan_out)), dtype=jnp.float32)
    bias = jnp.asarray(rng.normal(size=(fan_out,)), dtype=jnp.float32)
    wq, wscale = quant.quantize_channels(w)
    return h, w, bias, wq, wscale


@pytest.mark.parametrize("relu", [True, False])
@pytest.mark.parametrize("b,fan_in,fan_out", [
    (1, 1, 1), (4, 16, 8), (32, 80, 96), (33, 7, 5),   # odd, non-multiple
])
def test_dense_matmul_q8_kernel_matches_ref(relu, b, fan_in, fan_out):
    """The Pallas kernel (interpret mode) is bitwise equal to the jitted
    jnp twin — same int32 accumulate, same epilogue multiply order."""
    rng = np.random.default_rng(b * 101 + fan_in)
    h, _, bias, wq, wscale = _q8_mlp_layer(rng, b, fan_in, fan_out)
    want = ops.dense_matmul_q8(h, wq, wscale, bias, relu=relu,
                               strategy="jnp")
    got = ops.dense_matmul_q8(h, wq, wscale, bias, relu=relu,
                              strategy="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("relu", [True, False])
def test_dense_matmul_q8_error_bound_vs_fp32(relu):
    """Quantized output stays within the propagated grid-step budget of
    the fp32 matmul: each product term errs by at most
    (|h|·ws/2 + |w|·hs/2 + hs·ws/4) so the row-sum bound is linear in
    fan_in; ReLU never widens it (1-Lipschitz)."""
    rng = np.random.default_rng(7)
    b, fan_in, fan_out = 16, 64, 32
    h, w, bias, wq, wscale = _q8_mlp_layer(rng, b, fan_in, fan_out)
    hscale = quant.absmax_scale(h, axis=-1)

    exact = np.asarray(h) @ np.asarray(w) + np.asarray(bias)[None, :]
    if relu:
        exact = np.maximum(exact, 0.0)
    got = np.asarray(ops.dense_matmul_q8(h, wq, wscale, bias, relu=relu,
                                         strategy="jnp"))

    hs, ws = np.asarray(hscale), np.asarray(wscale)
    habs, wabs = np.abs(np.asarray(h)), np.abs(np.asarray(w))
    bound = (habs @ (np.ones_like(wabs) * ws) * 0.5
             + (np.ones_like(habs) * hs) @ wabs * 0.5
             + fan_in * hs * ws * 0.25) + 1e-5
    assert np.all(np.abs(got - exact) <= bound)


def test_dense_matmul_q8_batch_grid_tiling():
    """Batches that straddle the block_b grid tile bitwise-match the
    single-tile result (same rows, different grid decomposition)."""
    rng = np.random.default_rng(3)
    h, _, bias, wq, wscale = _q8_mlp_layer(rng, 24, 16, 8)
    one = ops.dense_matmul_q8(h, wq, wscale, bias, strategy="pallas",
                              interpret=True)
    from repro.kernels.dense_matmul import dmm_q8
    hscale = quant.absmax_scale(h, axis=-1)
    hq = quant.quantize(h, hscale)
    tiled = dmm_q8(hq, hscale, wq, wscale, bias.reshape(1, -1),
                   block_b=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(one), np.asarray(tiled))
