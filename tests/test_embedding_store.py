"""Tiered EmbeddingStore tests — the cache-aware parameter-server subsystem.

Acceptance surface of the tiered-store refactor: ``CachedStore`` lookups
bit-exact with ``DenseStore`` (uniform and zipf traffic, one-hot and
multi-hot, single-device and 1×1 mesh, before and after refresh), traffic
counters behaving (hit-rate/cached-fraction grow with skew), and the
placement regression — sharding is derived from the store's
``partition_spec()``, not from ``"mega" in names``, so renamed/nested
embedding params still shard correctly.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.compat import make_mesh
from repro.configs import ctr_spec
from repro.core import compile_plan
from repro.core.plan import _shard_params
from repro.data.synthetic import CRITEO, synthetic_batch, zipf_ids
from repro.embedding import (CachedStore, DenseStore,
                             FusedEmbeddingCollection, FusedEmbeddingSpec)
from repro.models.ctr import CTR_MODELS

SPEC = FusedEmbeddingSpec(field_sizes=(60, 7, 350, 90), dim=8)
SCHEMA = CRITEO.scaled(2_000)
SPEC_KW = dict(embed_dim=8, hidden=64, max_field=2_000)


def make_pair(capacity=48):
    """Dense and cached collections over the *same* table values."""
    dense = FusedEmbeddingCollection(SPEC)
    params_d = dense.init(jax.random.PRNGKey(0))
    store = CachedStore(SPEC, capacity=capacity)
    cached = FusedEmbeddingCollection(SPEC, store=store)
    params_c = store.from_dense(params_d)
    return dense, params_d, cached, params_c, store


def traffic(batch=128, exponent=None, seed=0):
    """(b, k) ids — zipf when an exponent is given, else uniform."""
    key = jax.random.PRNGKey(seed)
    if exponent is not None:
        return zipf_ids(key, batch, SPEC.field_sizes, exponent=exponent)
    rng = np.random.default_rng(seed)
    return jnp.asarray(np.stack([rng.integers(0, s, size=batch)
                                 for s in SPEC.field_sizes], axis=1),
                       dtype=jnp.int32)


# --- bit-exactness ----------------------------------------------------------

@pytest.mark.parametrize("exponent", [None, 1.3])
def test_cached_store_bit_exact_onehot(exponent):
    dense, pd, cached, pc, _ = make_pair()
    ids = traffic(exponent=exponent)
    want = np.asarray(dense.apply(pd, ids, strategy="jnp"))
    got = np.asarray(cached.apply(pc, ids, strategy="jnp"))
    np.testing.assert_array_equal(got, want)
    # kernel-body validation of the Pallas two-level gather
    got_pl = np.asarray(cached.apply(pc, ids[:16], strategy="pallas",
                                     interpret=True))
    np.testing.assert_array_equal(got_pl, want[:16])


@pytest.mark.parametrize("exponent", [None, 1.3])
def test_cached_store_bit_exact_multihot(exponent):
    dense, pd, cached, pc, _ = make_pair()
    h = 3
    rng = np.random.default_rng(1)
    if exponent is None:
        ids = np.stack([rng.integers(0, s, size=(64, h))
                        for s in SPEC.field_sizes], axis=1)
    else:
        ids = np.stack([np.asarray(zipf_ids(jax.random.PRNGKey(t), 64,
                                            SPEC.field_sizes, exponent))
                        for t in range(h)], axis=-1)
    ids = jnp.asarray(ids, dtype=jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, size=ids.shape), jnp.float32)
    want = np.asarray(dense.apply_multihot(pd, ids, mask, strategy="jnp"))
    got = np.asarray(cached.apply_multihot(pc, ids, mask, strategy="jnp"))
    np.testing.assert_array_equal(got, want)
    want_pl = np.asarray(dense.apply_multihot(pd, ids[:8], mask[:8],
                                              strategy="pallas",
                                              interpret=True))
    got_pl = np.asarray(cached.apply_multihot(pc, ids[:8], mask[:8],
                                              strategy="pallas",
                                              interpret=True))
    np.testing.assert_array_equal(got_pl, want_pl)


def test_cached_store_bit_exact_after_refresh():
    dense, pd, cached, pc, store = make_pair()
    ids = traffic(exponent=1.5)
    want = np.asarray(dense.apply(pd, ids, strategy="jnp"))
    cached.observe(np.asarray(ids))
    pc = store.refresh(pc)
    got = np.asarray(cached.apply(pc, ids, strategy="jnp"))
    np.testing.assert_array_equal(got, want)
    assert store.stats.refreshes == 1


def test_cached_capacity_clamps_to_rows():
    store = CachedStore(SPEC, capacity=10 * SPEC.rows)
    assert store.capacity == SPEC.rows
    coll = FusedEmbeddingCollection(SPEC, store=store)
    params = coll.init(jax.random.PRNGKey(0))
    ids = traffic(batch=32)
    dense = FusedEmbeddingCollection(SPEC)
    want = dense.apply(dense.init(jax.random.PRNGKey(0)), ids)
    np.testing.assert_array_equal(np.asarray(coll.apply(params, ids)),
                                  np.asarray(want))
    with pytest.raises(ValueError):
        CachedStore(SPEC, capacity=0)


# --- traffic counters -------------------------------------------------------

def test_hit_rate_and_cached_fraction_grow_with_skew():
    """At fixed capacity, zipfier traffic -> higher post-refresh hit rate
    and higher cached-traffic fraction (the HugeCTR premise)."""
    results = {}
    for exponent in (0.0, 1.1, 1.6):
        _, _, cached, pc, store = make_pair(capacity=32)
        for t in range(4):
            cached.observe(np.asarray(
                zipf_ids(jax.random.PRNGKey(t), 256, SPEC.field_sizes,
                         exponent=exponent)))
        pc = store.refresh(pc)
        h0, n0 = store.stats.hits, store.stats.lookups
        for t in range(4, 8):
            cached.observe(np.asarray(
                zipf_ids(jax.random.PRNGKey(t), 256, SPEC.field_sizes,
                         exponent=exponent)))
        rate = (store.stats.hits - h0) / (store.stats.lookups - n0)
        results[exponent] = (rate, store.cached_traffic_fraction)
    rates = [results[e][0] for e in (0.0, 1.1, 1.6)]
    fracs = [results[e][1] for e in (0.0, 1.1, 1.6)]
    assert rates == sorted(rates) and rates[0] < rates[-1], results
    assert fracs == sorted(fracs) and fracs[0] < fracs[-1], results


def test_refresh_admits_hot_rows_deterministically():
    _, _, cached, pc, store = make_pair(capacity=4)
    # all traffic on one id per field -> refresh must cache exactly those
    hot = np.array([[3, 2, 17, 5]] * 50, dtype=np.int64)
    cached.observe(hot)
    pc = store.refresh(pc)
    hot_rows = hot[0] + SPEC.offsets
    assert set(np.flatnonzero(np.asarray(pc["slot_of_row"]) >= 0)) \
        == set(hot_rows.tolist())
    h0 = store.stats.hits
    cached.observe(hot[:1])
    assert store.stats.hits - h0 == SPEC.k      # every lookup now hits
    assert store.cached_traffic_fraction == 1.0


def test_dense_store_counters_stay_zero():
    dense = FusedEmbeddingCollection(SPEC)
    dense.init(jax.random.PRNGKey(0))
    dense.observe(np.asarray(traffic(batch=8)))
    assert dense.store.stats.lookups == 0
    assert dense.store.cached_traffic_fraction == 1.0


# --- placement regression (the "mega" in names heuristic is gone) -----------

def test_partition_spec_shards_store_tables_by_structure():
    """Placement is derived from the store's partition_spec — the cached
    layout's leaves (backing/cache/slot_of_row) contain no "mega" yet the
    backing table still row-shards; cache tiers replicate."""
    spec = ctr_spec("dcnv2", "criteo", **SPEC_KW)
    model = CTR_MODELS["dcnv2"](
        spec, store=CachedStore(spec.embedding_spec(), capacity=64))
    params = model.init(jax.random.PRNGKey(0))
    mesh = make_mesh((1, 1), ("data", "model"))
    placed = _shard_params(params, mesh, "model",
                           model.partition_spec(params, "model"))
    backing_spec = placed["emb"]["backing"].sharding.spec
    assert tuple(backing_spec)[:1] == ("model",)
    assert tuple(placed["emb"]["cache"].sharding.spec) == ()
    assert tuple(placed["emb"]["slot_of_row"].sharding.spec) == ()
    assert tuple(placed["head"]["w"].sharding.spec) == ()


@pytest.mark.parametrize("model_name", ["dcnv2", "widedeep"])
def test_cached_model_on_mesh_matches_dense(model_name):
    spec = ctr_spec(model_name, "criteo", **SPEC_KW)
    dense_model = CTR_MODELS[model_name](spec)
    params = dense_model.init(jax.random.PRNGKey(0))
    ids = np.asarray(synthetic_batch(SCHEMA, 0, 16)["ids"])
    want = compile_plan(dense_model, params, "dual", 16).predict(ids)

    cmodel = CTR_MODELS[model_name](
        spec, store=CachedStore(spec.embedding_spec(), capacity=128))
    cparams = cmodel.init(jax.random.PRNGKey(0))
    got = compile_plan(cmodel, cparams, "dual", 16).predict(ids)
    np.testing.assert_array_equal(got, want)

    mesh = make_mesh((1, 1), ("data", "model"))
    got_mesh = compile_plan(cmodel, cparams, "dual", 16,
                            mesh=mesh).predict(ids)
    np.testing.assert_allclose(got_mesh, want, rtol=1e-6, atol=1e-6)


def test_plan_key_distinguishes_stores():
    spec = ctr_spec("dcn", "criteo", **SPEC_KW)
    dense_model = CTR_MODELS["dcn"](spec)
    cmodel = CTR_MODELS["dcn"](
        spec, store=CachedStore(spec.embedding_spec(), capacity=64))
    params = dense_model.init(jax.random.PRNGKey(0))
    pk_dense = compile_plan(dense_model, params, "dual", 8).key
    pk_cached = compile_plan(cmodel, cmodel.init(jax.random.PRNGKey(0)),
                             "dual", 8).key
    assert pk_dense != pk_cached
    assert pk_dense.store.startswith("dense")
    assert pk_cached.store.startswith("cached")


def test_executor_stats_carry_store_identity():
    spec = ctr_spec("dcn", "criteo", **SPEC_KW)
    model = CTR_MODELS["dcn"](
        spec, store=CachedStore(spec.embedding_spec(), capacity=64))
    plan = compile_plan(model, model.init(jax.random.PRNGKey(0)), "dual", 8)
    assert plan.stats.embedding_store.startswith("cached(C=64")


# --- store adoption ---------------------------------------------------------

def test_use_store_converts_params_bit_exactly():
    spec = ctr_spec("deepfm", "criteo", **SPEC_KW)
    model = CTR_MODELS["deepfm"](spec)
    params = model.init(jax.random.PRNGKey(0))
    ids = np.asarray(synthetic_batch(SCHEMA, 0, 8)["ids"])
    want = compile_plan(model, params, "dual", 8).predict(ids)
    store = CachedStore(spec.embedding_spec(), capacity=64)
    params2 = model.use_store(store, params)
    assert set(params2["emb"]) == {"backing", "cache", "slot_of_row"}
    assert isinstance(model.embedding.store, CachedStore)
    got = compile_plan(model, params2, "dual", 8).predict(ids)
    np.testing.assert_array_equal(got, want)
    # round-trip back to dense
    params3 = model.use_store(DenseStore(spec.embedding_spec()), params2)
    np.testing.assert_array_equal(
        np.asarray(params3["emb"]["mega_table"]),
        np.asarray(params["emb"]["mega_table"]))


def test_observe_clips_malformed_ids():
    """One out-of-range or negative id must not wedge the serving loop —
    observe clips exactly like the gather (jnp.take clamps) does."""
    _, _, cached, pc, store = make_pair()
    bad = np.array([[10**9, -5, 2, 1]], dtype=np.int64)
    cached.observe(bad)                          # must not raise
    assert store.stats.lookups == SPEC.k


def test_dense_engine_refresh_every_is_a_noop():
    """A dense engine with refresh_every set must never drop its plans
    (DenseStore has no cache tier to rebuild)."""
    from repro.serving import FixedBatch, InferenceEngine
    spec = ctr_spec("dcn", "criteo", **SPEC_KW)
    model = CTR_MODELS["dcn"](spec)
    params = model.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(model, params, policy=FixedBatch(8),
                          refresh_every=1)
    rng = np.random.default_rng(0)
    rows = [np.array([rng.integers(0, s) for s in spec.field_sizes],
                     dtype=np.int32) for _ in range(16)]
    eng.submit_many(rows)
    eng.serve_pending()
    assert len(eng.cached_plans) == 1            # plans survive
    assert eng.stats.cache_misses == 1           # compiled exactly once
    assert eng.stats.emb_cache_refreshes == 0
    assert eng.stats.emb_cached_traffic_fraction == 0.0
