"""Online model updates — versioned delta-stream refresh.

Acceptance surface of the live-trainer intake path: ``push_update`` /
``pull_updates`` apply ``(row_id, new_row)`` deltas through the
double-buffered publish with **zero plan recompiles**, every publish
stamps the next monotonic ``emb_version`` (torn or backward reads are
impossible — hard-asserted inside ``_runtime_env`` on every compiled
step, exercised here under concurrent serve+push), int8 tiers
re-quantize incoming fp32 rows onto the same grid a cold store would
produce, two engines sharing one ``CachedStore`` stay version-pinned
independently (the A/B scenario), staleness gauges measure the attached
source's real backlog, and ``DenseStore`` — whose tensors are baked plan
constants — refuses the whole surface loudly.
"""

import threading

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ctr_spec
from repro.data.synthetic import CRITEO, zipf_ids
from repro.embedding import CachedStore, DenseStore, HostBackedStore
from repro.embedding.store import validate_deltas
from repro.models.ctr import CTR_MODELS
from repro.serving import (DeltaBuffer, FixedBatch, InferenceEngine,
                           ServingRuntime, SyntheticTrainer)

SCHEMA = CRITEO.scaled(2_000)
SPEC = ctr_spec("widedeep", "criteo", embed_dim=8, hidden=64,
                max_field=2_000)
ESPEC = SPEC.embedding_spec()


def fresh_model():
    """One model instance per engine: an engine binds its store to the
    model's collection at construction."""
    model = CTR_MODELS["widedeep"](SPEC)
    return model, model.init(jax.random.PRNGKey(0))


def traffic(n=64, seed=1):
    return np.asarray(zipf_ids(jax.random.PRNGKey(seed), n,
                               SCHEMA.field_sizes, exponent=1.1))


def make_engine(store, batch=16):
    model, params = fresh_model()
    return InferenceEngine(model, params, policy=FixedBatch(batch),
                           store=store)


def deltas(n_rows=32, seed=0):
    rng = np.random.default_rng(seed)
    ids = rng.choice(ESPEC.zero_row, size=n_rows, replace=False)
    rows = (rng.standard_normal((n_rows, ESPEC.dim)) * 0.1).astype(
        np.float32)
    return ids, rows


# --- validate_deltas: the shared intake contract -----------------------------

def test_validate_deltas_rejects_zero_and_padding_rows():
    ids = np.array([0, ESPEC.zero_row])      # second id IS the zero row
    rows = np.zeros((2, ESPEC.dim), np.float32)
    with pytest.raises(ValueError, match="zero row"):
        validate_deltas(ESPEC, ids, rows)
    with pytest.raises(ValueError, match="out of range"):
        validate_deltas(ESPEC, np.array([-1]), rows[:1])


def test_validate_deltas_duplicates_keep_last_occurrence():
    ids = np.array([5, 9, 5])
    rows = np.stack([np.full(ESPEC.dim, v, np.float32)
                     for v in (1.0, 2.0, 3.0)])
    out_ids, out_rows = validate_deltas(ESPEC, ids, rows)
    got = dict(zip(out_ids.tolist(), out_rows[:, 0].tolist()))
    assert got == {5: 3.0, 9: 2.0}            # stream order wins


def test_validate_deltas_shape_mismatch_and_empty():
    with pytest.raises(ValueError, match="shape"):
        validate_deltas(ESPEC, np.array([1, 2]),
                        np.zeros((2, ESPEC.dim + 1), np.float32))
    out_ids, out_rows = validate_deltas(ESPEC, np.array([], np.int64),
                                        np.zeros((0, ESPEC.dim)))
    assert out_ids.size == 0 and out_rows.shape == (0, ESPEC.dim)


# --- engine push path --------------------------------------------------------

def test_dense_store_rejects_online_deltas():
    eng = make_engine(None)                   # default DenseStore semantics
    ids, rows = deltas(4)
    with pytest.raises(ValueError, match="refreshable"):
        eng.push_update(ids, rows)
    with pytest.raises(NotImplementedError, match="constants"):
        DenseStore(ESPEC).apply_deltas({}, ids, rows)


@pytest.mark.parametrize("store_cls", [CachedStore, HostBackedStore])
def test_push_update_changes_scores_with_zero_recompiles(store_cls):
    eng = make_engine(store_cls(ESPEC, capacity=64))
    ids = traffic(32)
    before = eng.predict(ids)
    compiles = eng.stats.cache_misses
    plans = set(eng.cached_plans)

    d_ids, d_rows = deltas(48, seed=3)
    applied = eng.push_update(d_ids, d_rows)
    assert applied == 48
    assert eng.stats.emb_version == 1
    assert eng.stats.emb_delta_pushes == 1
    assert eng.stats.emb_delta_rows == 48

    after = eng.predict(ids)
    assert eng.stats.cache_misses == compiles
    assert set(eng.cached_plans) == plans
    assert before.shape == after.shape
    # value parity is pinned bit-exactly against a rebuilt reference in
    # test_pushed_scores_bitexact_with_rebuilt_dense_reference


def test_empty_push_applies_nothing_and_keeps_version():
    eng = make_engine(CachedStore(ESPEC, capacity=64))
    assert eng.push_update(np.array([], np.int64),
                           np.zeros((0, ESPEC.dim), np.float32)) == 0
    assert eng.stats.emb_version == 0 and eng.stats.emb_delta_pushes == 0


@pytest.mark.parametrize("store_cls", [CachedStore, HostBackedStore])
def test_pushed_scores_bitexact_with_rebuilt_dense_reference(store_cls):
    """fp32 contract: serving after N pushes == a cold engine built from
    a table with the same deltas applied (numpy fancy assignment keeps
    the last duplicate, matching ``validate_deltas``)."""
    eng = make_engine(store_cls(ESPEC, capacity=64))
    ids = traffic(32)
    eng.predict(ids)                          # pin the plan first

    ref_model, ref_params = fresh_model()
    table = np.array(ref_params[ref_model.main_embedding_key]["mega_table"])
    for seed in range(3):
        d_ids, d_rows = deltas(32, seed=seed)
        eng.push_update(d_ids, d_rows)
        table[d_ids] = d_rows
    key = ref_model.main_embedding_key
    ref_params = {**ref_params,
                  key: {**ref_params[key], "mega_table": jnp.asarray(table)}}
    ref = InferenceEngine(ref_model, ref_params, policy=FixedBatch(16))
    np.testing.assert_array_equal(eng.predict(ids), ref.predict(ids))
    assert eng.stats.emb_version == 3


@pytest.mark.parametrize("store_cls", [CachedStore, HostBackedStore])
def test_int8_requant_parity_with_cold_store(store_cls):
    """Re-quantization contract: pushing fp32 rows through an int8 tier
    lands on the identical int8 grid as loading the delta-applied table
    into a cold int8 store — bit-exact scores, not just close."""
    eng = make_engine(store_cls(ESPEC, capacity=64, row_dtype="int8"))
    ids = traffic(32)
    eng.predict(ids)
    quant_before = eng.store.stats.quant_rows

    ref_model, ref_params = fresh_model()
    table = np.array(ref_params[ref_model.main_embedding_key]["mega_table"])
    d_ids, d_rows = deltas(48, seed=7)
    eng.push_update(d_ids, d_rows)
    table[d_ids] = d_rows
    assert eng.store.stats.quant_rows == quant_before + 48

    key = ref_model.main_embedding_key
    ref_params = {**ref_params,
                  key: {**ref_params[key], "mega_table": jnp.asarray(table)}}
    ref = InferenceEngine(ref_model, ref_params, policy=FixedBatch(16),
                          store=store_cls(ESPEC, capacity=64,
                                          row_dtype="int8"))
    np.testing.assert_array_equal(eng.predict(ids), ref.predict(ids))


def test_shared_cached_store_pins_ab_versions_independently():
    """Two engines over ONE CachedStore object: a push through ``prod``
    must not leak into ``shadow`` — its published subtree pins the
    pre-push tensors (device tensors are immutable) — and replaying the
    identical stream into ``shadow`` reconverges bit-exactly."""
    shared = CachedStore(ESPEC, capacity=64)
    prod = make_engine(shared)
    shadow = make_engine(shared)
    ids = traffic(32)
    np.testing.assert_array_equal(prod.predict(ids), shadow.predict(ids))
    baseline = shadow.predict(ids)

    stream = SyntheticTrainer(ESPEC, rows_per_batch=32, n_batches=2, seed=5)
    while (batch := stream.next_batch()) is not None:
        prod.push_update(*batch)
    assert prod.stats.emb_version == 2 and shadow.stats.emb_version == 0
    np.testing.assert_array_equal(shadow.predict(ids), baseline)

    replay = stream.replay()
    while (batch := replay.next_batch()) is not None:
        shadow.push_update(*batch)
    np.testing.assert_array_equal(shadow.predict(ids), prod.predict(ids))
    assert shadow.stats.emb_version == 2


def test_version_monotonic_under_concurrent_serve_and_push():
    """The torn-update test: a serving thread hammers ``predict`` while
    the main thread streams pushes. ``_runtime_env`` hard-asserts the
    version floor on every compiled step, so any backward or torn read
    raises out of the serving thread."""
    eng = make_engine(CachedStore(ESPEC, capacity=64))
    ids = traffic(16)
    eng.predict(ids)                          # compile outside the race
    errors = []
    stop = threading.Event()

    def serve():
        try:
            while not stop.is_set():
                eng.predict(ids)
        except BaseException as e:            # noqa: BLE001 — the assert IS the test
            errors.append(e)

    t = threading.Thread(target=serve)
    t.start()
    try:
        for seed in range(30):
            eng.push_update(*deltas(16, seed=seed))
    finally:
        stop.set()
        t.join()
    assert not errors, errors
    assert eng.stats.emb_version == 30
    assert eng._version_floor <= 30           # floor only ever chases pushes


# --- delta sources and staleness ---------------------------------------------

def test_delta_buffer_is_fifo_and_validates_lengths():
    buf = DeltaBuffer()
    with pytest.raises(ValueError, match="row ids"):
        buf.feed([1, 2], np.zeros((3, ESPEC.dim), np.float32))
    buf.feed([1], np.full(ESPEC.dim, 1.0, np.float32))
    buf.feed([2], np.full(ESPEC.dim, 2.0, np.float32))
    assert buf.pending_rows() == 2
    first = buf.next_batch()
    assert first[0].tolist() == [1] and float(first[1][0, 0]) == 1.0
    assert buf.next_batch()[0].tolist() == [2]
    assert buf.next_batch() is None and buf.pending_rows() == 0


def test_staleness_gauges_with_injected_clock():
    now = [100.0]
    buf = DeltaBuffer(clock=lambda: now[0])
    eng = make_engine(CachedStore(ESPEC, capacity=64))
    eng.attach_delta_source(buf)
    assert eng.stats.rows_behind == 0 and eng.stats.seconds_behind == 0.0

    d_ids, d_rows = deltas(8, seed=2)
    buf.feed(d_ids, d_rows)
    now[0] += 4.0
    eng.poll_staleness()
    assert eng.stats.rows_behind == 8
    assert eng.stats.seconds_behind == pytest.approx(4.0)

    assert eng.pull_updates() == 8
    assert eng.stats.rows_behind == 0 and eng.stats.seconds_behind == 0.0
    assert eng.stats.emb_version == 1


def test_synthetic_trainer_is_finite_seeded_and_replayable():
    tr = SyntheticTrainer(ESPEC, rows_per_batch=8, n_batches=3, seed=11)
    batches = []
    while (b := tr.next_batch()) is not None:
        batches.append(b)
    assert len(batches) == 3 and tr.pending_rows() == 0
    again = tr.replay()
    for ids, rows in batches:
        r_ids, r_rows = again.next_batch()
        np.testing.assert_array_equal(ids, r_ids)
        np.testing.assert_array_equal(rows, r_rows)
    assert all(ids.max() < ESPEC.zero_row for ids, _ in batches)


# --- host backing persistence ------------------------------------------------

def test_host_open_readonly_rejects_deltas_rplus_persists(tmp_path):
    path = tmp_path / "backing.bin"
    seeded = HostBackedStore(ESPEC, capacity=64, backing_path=path)
    seeded.init(jax.random.PRNGKey(0))

    ro = HostBackedStore.open(ESPEC, capacity=64, backing_path=path)
    params = ro.device_params()
    d_ids, d_rows = deltas(8, seed=4)
    with pytest.raises(ValueError, match="mode='r\\+'"):
        ro.apply_deltas(params, d_ids, d_rows)

    rw = HostBackedStore.open(ESPEC, capacity=64, backing_path=path,
                              mode="r+")
    _, n = rw.apply_deltas(rw.device_params(), d_ids, d_rows)
    assert n == 8
    # deltas landed on disk: a third, read-only open sees the new values
    check = HostBackedStore.open(ESPEC, capacity=64, backing_path=path)
    np.testing.assert_array_equal(check.host_view()[d_ids], d_rows)


# --- runtime surface ---------------------------------------------------------

def test_runtime_routes_pushes_and_aggregates_versions():
    rt = ServingRuntime()
    m_a, p_a = fresh_model()
    m_b, p_b = fresh_model()
    rt.add_model("a", m_a, p_a, policy=FixedBatch(16),
                 store=CachedStore(ESPEC, capacity=64))
    rt.add_model("b", m_b, p_b, policy=FixedBatch(16),
                 store=CachedStore(ESPEC, capacity=64))
    rt.warmup()
    for seed in range(3):
        rt.push_update("a", *deltas(16, seed=seed))
    rt.push_update("b", *deltas(16, seed=9))
    st = rt.stats()
    assert rt.engine("a").stats.emb_version == 3
    assert rt.engine("b").stats.emb_version == 1
    assert st.emb_version == 3                # MAX across engines, not sum
    assert st.emb_delta_pushes == 4           # counters DO sum
    assert st.emb_delta_rows == 64


def test_runtime_delta_every_drains_stream_under_live_traffic():
    """The ``delta_every`` cadence: background pulls ride admission
    counting; by stream end the trainer is fully drained, versions
    accounted, with zero recompiles — the benchmark's contract, in
    miniature."""
    model, params = fresh_model()
    rt = ServingRuntime(delta_every=8)
    rt.add_model("m", model, params, policy=FixedBatch(1),
                 store=CachedStore(ESPEC, capacity=64), worker_tick_ms=1.0)
    trainer = SyntheticTrainer(ESPEC, rows_per_batch=16, n_batches=2,
                               seed=0)
    rt.attach_delta_stream("m", trainer)
    rt.warmup()
    eng = rt.engine("m")
    compiles = eng.stats.cache_misses

    rt.start()
    try:
        futs = [rt.submit("m", row) for row in traffic(32)]
        for f in futs:
            f.result(timeout=60.0)
    finally:
        rt.stop()
    rt.pull_updates()                         # leftovers, deterministically
    st = rt.stats()
    assert st.emb_version == 2 and st.emb_delta_rows == 32
    assert st.rows_behind == 0 and st.seconds_behind == 0.0
    assert eng.stats.cache_misses == compiles
