"""Cross-engine continuous batching (ISSUE-9 acceptance surface).

Covers: the shared-pool thread budget (N=8 models, pool=2 → at most
pool_size + 1 new threads, hard-asserted) with scores bit-exact vs
per-engine-worker mode, SLO-slack scheduling (a starved low-traffic
model behind a high-traffic one still meets its ``TimeoutBatch``
deadline), per-engine backpressure under the shared pool, the
``next_ready`` readiness view semantics, cross-intake-stream request
coalescing, per-model device-time accounting, and the worker-error
surfacing contract (``n_worker_errors`` + re-raise from ``stop()``).
"""

import threading
import time

import numpy as np
import pytest
import jax

from repro.configs import ctr_spec
from repro.data.synthetic import CRITEO
from repro.models.ctr import CTR_MODELS
from repro.serving import (BucketedBatch, DeviceScheduler, FixedBatch,
                           InferenceEngine, QueueFullError, ServingRuntime,
                           TimeoutBatch)

SCHEMA = CRITEO.scaled(2_000)
SPEC_KW = dict(embed_dim=8, hidden=64, max_field=2_000)


def make(model_name="widedeep", seed=0):
    spec = ctr_spec(model_name, "criteo", **SPEC_KW)
    model = CTR_MODELS[model_name](spec)
    return model, model.init(jax.random.PRNGKey(seed))


def rows_of(n, seed=0):
    rng = np.random.default_rng(seed)
    return [np.array([rng.integers(0, s) for s in SCHEMA.field_sizes],
                     dtype=np.int32) for _ in range(n)]


def build_runtime(n_models, scheduler, pool_size=2, max_wait_ms=3.0,
                  ladder=(8, 16)):
    rt = ServingRuntime(scheduler=scheduler, pool_size=pool_size)
    for i in range(n_models):
        model, params = make(seed=i)
        rt.add_model(f"m{i}", model, params,
                     policy=TimeoutBatch(BucketedBatch(ladder),
                                         max_wait_ms=max_wait_ms),
                     worker_tick_ms=1.0)
    rt.warmup()
    return rt


def drive(rt, rows):
    names = rt.models
    futs = [rt.submit(names[i % len(names)], row)
            for i, row in enumerate(rows)]
    return np.array([f.result(timeout=120.0) for f in futs])


# --- acceptance: thread budget + bit-exactness --------------------------------

def test_eight_models_two_threads_bit_exact():
    """Acceptance: hosting N=8 models on a pool of 2 spawns at most
    pool_size + 1 threads (instead of 8 per-engine workers), and every
    score is bit-exact with per-engine-worker mode on the same traffic."""
    rows = rows_of(96)

    shared = build_runtime(8, "shared", pool_size=2)
    before = threading.active_count()
    shared.start()
    got, peak = None, threading.active_count()
    try:
        got = drive(shared, rows)
        peak = max(peak, threading.active_count())
    finally:
        shared.stop()
    assert peak - before <= 2 + 1, (peak, before)     # pool_size + 1, not N
    agg = shared.stats()
    assert agg.n_requests == 96 and agg.queue_depth == 0

    per_engine = build_runtime(8, "per-engine")
    before = threading.active_count()
    per_engine.start()
    try:
        want = drive(per_engine, rows)
        workers = threading.active_count() - before
    finally:
        per_engine.stop()
    assert workers >= 8                                # the old cost: N threads
    np.testing.assert_array_equal(got, want)           # bit-exact across modes


def test_device_time_share_and_dispatch_counters():
    rt = build_runtime(3, "shared", pool_size=2)
    rt.start()
    try:
        drive(rt, rows_of(48))
    finally:
        rt.stop()
    agg = rt.stats()
    assert agg.sched_dispatches >= 3                   # every model dispatched
    assert abs(agg.device_time_share - 1.0) < 1e-9     # shares sum to 1
    for name in rt.models:
        st = agg.per_model[name]
        assert st.sched_dispatches >= 1
        assert 0.0 < st.device_time_share < 1.0
        assert st.sched_preempted_slack_ms >= 0.0
    sched = rt.scheduler
    assert sched is not None and not sched.running     # stopped with the rt
    assert sched.n_dispatches == agg.sched_dispatches
    assert abs(sum(sched.shares.values()) - 1.0) < 1e-9


# --- SLO-slack fairness -------------------------------------------------------

def test_starved_model_meets_slo_behind_heavy_traffic():
    """A low-traffic model's due TimeoutBatch partial outranks the heavy
    model's endless full buckets: its 3 requests must resolve promptly
    (least-slack pick), not starve behind the high-traffic stream."""
    rt = ServingRuntime(pool_size=2)
    heavy_model, heavy_params = make(seed=0)
    rt.add_model("heavy", heavy_model, heavy_params,
                 policy=TimeoutBatch(FixedBatch(16), max_wait_ms=50.0),
                 worker_tick_ms=1.0)
    starved_model, starved_params = make(seed=1)
    rt.add_model("starved", starved_model, starved_params,
                 policy=TimeoutBatch(FixedBatch(16), max_wait_ms=10.0),
                 worker_tick_ms=1.0)
    rt.warmup()
    rt.start()
    stop_flag = threading.Event()

    def hammer():
        while not stop_flag.is_set():
            for f in [rt.submit("heavy", r) for r in rows_of(32)]:
                f.result(timeout=120.0)

    t = threading.Thread(target=hammer)
    t.start()
    try:
        time.sleep(0.05)                       # heavy stream in full swing
        futs = [rt.submit("starved", r) for r in rows_of(3, seed=9)]
        t0 = time.perf_counter()
        for f in futs:
            f.result(timeout=30.0)
        waited_ms = (time.perf_counter() - t0) * 1e3
    finally:
        stop_flag.set()
        t.join()
        rt.stop()
    # SLO is 10ms; generous CI headroom, but nowhere near starvation
    assert waited_ms < 5_000.0, waited_ms
    st = rt.stats().per_model["starved"]
    assert st.n_requests == 3 and st.sched_dispatches >= 1


def test_backpressure_stays_per_engine_under_shared_pool():
    """max_queue_depth keeps rejecting per engine with the shared pool
    running: one bounded engine sheds load while its neighbour accepts."""
    rt = ServingRuntime(pool_size=2)
    m0, p0 = make(seed=0)
    # a policy that never dispatches on its own: partial held ~forever
    rt.add_model("bounded", m0, p0,
                 policy=TimeoutBatch(FixedBatch(64), max_wait_ms=60_000.0),
                 max_queue_depth=4)
    m1, p1 = make(seed=1)
    rt.add_model("free", m1, p1,
                 policy=TimeoutBatch(FixedBatch(8), max_wait_ms=2.0),
                 worker_tick_ms=1.0)
    rt.warmup()
    rt.start()
    try:
        kept = [rt.submit("bounded", r) for r in rows_of(4)]
        rejected = rt.submit("bounded", rows_of(1, seed=5)[0])
        assert rejected.done()
        with pytest.raises(QueueFullError):
            rejected.result(timeout=0)
        # the neighbour engine is unaffected by the bounded one's shedding
        ok = [rt.submit("free", r) for r in rows_of(6, seed=7)]
        for f in ok:
            f.result(timeout=60.0)
    finally:
        rt.stop()                              # flush resolves the kept 4
    assert all(f.done() for f in kept)
    st = rt.stats()
    assert st.n_rejected == 1
    assert st.per_model["bounded"].n_rejected == 1
    assert st.per_model["free"].n_rejected == 0


# --- readiness view -----------------------------------------------------------

def test_next_ready_full_bucket_due_now():
    model, params = make()
    eng = InferenceEngine(model, params, policy=BucketedBatch((8, 16)))
    assert eng.next_ready() is None            # empty queue
    eng.submit_many(rows_of(19))
    c = eng.next_ready()
    assert (c.take, c.bucket, c.partial) == (16, 16, False)
    assert c.slack_ms == 0.0                   # full buckets are due now
    eng.flush()
    assert eng.next_ready() is None


def test_next_ready_timeout_partial_carries_slo_slack():
    model, params = make()
    eng = InferenceEngine(model, params,
                          policy=TimeoutBatch(FixedBatch(8),
                                              max_wait_ms=200.0))
    eng.submit(rows_of(1)[0])
    c = eng.next_ready()
    assert c.partial and (c.take, c.bucket) == (1, 8)
    assert 0.0 < c.slack_ms <= 200.0           # deadline minus queue age
    later = eng.next_ready(time.perf_counter() + 1.0)
    assert later.slack_ms < 0.0                # past the deadline: overdue
    eng.flush()


def test_next_ready_default_grace_for_deadline_free_policies():
    model, params = make()
    eng = InferenceEngine(model, params, policy=FixedBatch(8),
                          worker_tick_ms=5.0)
    eng.submit_many(rows_of(3))
    c = eng.next_ready()
    assert c.partial and c.slack_ms <= 8 * 5.0  # the worker-loop grace
    eng.flush()


def test_scheduler_picks_least_slack_candidate():
    sched = DeviceScheduler(pool_size=1)
    model_a, params_a = make(seed=0)
    a = InferenceEngine(model_a, params_a,
                        policy=TimeoutBatch(FixedBatch(8), max_wait_ms=5.0))
    model_b, params_b = make(seed=1)
    b = InferenceEngine(model_b, params_b,
                        policy=TimeoutBatch(FixedBatch(8), max_wait_ms=500.0))
    sched.attach("a", a)
    sched.attach("b", b)
    b.submit(rows_of(1, seed=1)[0])            # due much later
    a.submit(rows_of(1, seed=0)[0])            # due in 5ms
    name, cand, _ = sched._pick(time.perf_counter() + 0.05)
    assert name == "a" and cand.partial        # most overdue deadline first
    a.flush()
    b.flush()


def test_attach_rejects_conflicts():
    sched = DeviceScheduler(pool_size=1)
    model, params = make()
    eng = InferenceEngine(model, params, policy=FixedBatch(8))
    sched.attach("m", eng)
    sched.attach("m", eng)                     # idempotent
    other_model, other_params = make(seed=1)
    other = InferenceEngine(other_model, other_params, policy=FixedBatch(8))
    with pytest.raises(ValueError, match="already attached"):
        sched.attach("m", other)
    with pytest.raises(ValueError, match="another scheduler"):
        DeviceScheduler(pool_size=1).attach("m", eng)
    with pytest.raises(ValueError, match="pool_size"):
        DeviceScheduler(pool_size=0)


# --- coalescing ---------------------------------------------------------------

def test_coalesces_requests_across_intake_streams():
    """Two submitter threads feed one model; the scheduler serves their
    union as one full device batch (n_batches == 1) — same-model
    requests coalesce across intake streams before dispatch."""
    model, params = make()
    eng = InferenceEngine(model, params,
                          policy=TimeoutBatch(FixedBatch(8),
                                              max_wait_ms=60_000.0))
    eng.warmup()
    sched = DeviceScheduler(pool_size=2)
    sched.attach("m", eng)
    sched.start()
    futs, lock = [], threading.Lock()

    def intake(seed):
        for f in eng.submit_many(rows_of(4, seed=seed)):
            with lock:
                futs.append(f)

    threads = [threading.Thread(target=intake, args=(s,)) for s in (1, 2)]
    try:
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for f in futs:
            f.result(timeout=60.0)
    finally:
        sched.stop()
    # the full bucket only exists as the union of both streams' submits
    assert eng.stats.n_batches == 1
    assert eng.stats.batches_per_bucket == {8: 1}
    assert eng.stats.sched_dispatches == 1


# --- worker-error surfacing (ISSUE-9 satellite) -------------------------------

def test_worker_error_counted_and_reraised_from_stop():
    """A background-drain failure (ragged row) fails its batch's futures
    immediately, bumps n_worker_errors, and stop() re-raises the last
    error instead of swallowing it forever."""
    model, params = make()
    eng = InferenceEngine(model, params,
                          policy=TimeoutBatch(FixedBatch(8), max_wait_ms=5.0),
                          worker_tick_ms=1.0)
    eng.warmup()
    eng.start()
    futs = eng.submit_many(rows_of(2))
    bad = eng.submit(np.zeros(len(SCHEMA.field_sizes) + 1, dtype=np.int32))
    for f in futs + [bad]:
        with pytest.raises(ValueError):
            f.result(timeout=60.0)             # batch failed, not stranded
    assert eng.stats.n_worker_errors == 1
    with pytest.raises(ValueError):
        eng.stop()                             # surfaces the swallowed error
    eng.stop()                                 # idempotent once drained


def test_worker_error_surfaced_through_shared_pool_and_runtime_stop():
    rt = ServingRuntime(pool_size=2)
    model, params = make()
    rt.add_model("m", model, params,
                 policy=TimeoutBatch(FixedBatch(8), max_wait_ms=5.0),
                 worker_tick_ms=1.0)
    rt.warmup()
    rt.start()
    futs = rt.submit_many("m", rows_of(2))
    bad = rt.submit("m", np.zeros(len(SCHEMA.field_sizes) + 1,
                                  dtype=np.int32))
    for f in futs + [bad]:
        with pytest.raises(ValueError):
            f.result(timeout=60.0)
    with pytest.raises(ValueError):
        rt.stop()                              # pool error resurfaces here
    assert rt.stats().n_worker_errors == 1
    rt.stop()                                  # idempotent once drained
