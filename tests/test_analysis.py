"""Roofline-analyzer unit tests: loop-corrected HLO accounting on programs
with known ground truth."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.analysis.hlo_parse import parse_hlo
from repro.analysis.analytic import model_flops, param_stats
from repro.compat import cost_analysis


def test_dot_flops_loop_corrected():
    L, n = 7, 64

    def f(x, w):
        def step(c, wi):
            return c @ wi, None
        return jax.lax.scan(step, x, w)[0]

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((L, n, n), jnp.float32)).compile()
    st = parse_hlo(c.as_text())
    expect = 2 * n**3 * L
    assert abs(st.dot_flops - expect) / expect < 0.01
    # raw cost_analysis counts the body once — the analyzer must not
    assert cost_analysis(c)["flops"] < expect / 2
    assert st.trip_counts == [L]


def test_dot_flops_with_tpu_tiled_layouts():
    """Inline operand shapes may carry TPU tiling in the layout
    (``{1,0:T(8,128)}``); the contraction dim must still be read."""
    hlo = """\
ENTRY %main.1 (a: f32[64,32], b: f32[32,16]) -> f32[64,16] {
  %Arg_0.1 = f32[64,32]{1,0:T(8,128)} parameter(0)
  %Arg_1.2 = f32[32,16]{1,0:T(8,128)} parameter(1)
  ROOT %dot.3 = f32[64,16]{1,0:T(8,128)} dot(f32[64,32]{1,0:T(8,128)} %Arg_0.1, f32[32,16]{1,0:T(8,128)} %Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    st = parse_hlo(hlo)
    assert st.dot_flops == 2 * 64 * 16 * 32


def test_nested_loop_multipliers():
    L1, L2, n = 3, 4, 32

    def f(x, w):
        def outer(c, wi):
            def inner(c2, _):
                return c2 @ wi, None
            return jax.lax.scan(inner, c, None, length=L2)[0], None
        return jax.lax.scan(outer, x, w)[0]

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((L1, n, n), jnp.float32)).compile()
    st = parse_hlo(c.as_text())
    expect = 2 * n**3 * L1 * L2
    assert abs(st.dot_flops - expect) / expect < 0.01


def test_collective_bytes_counted_once_per_op():
    from jax.sharding import NamedSharding, PartitionSpec as P
    if jax.device_count() < 2:
        import pytest
        pytest.skip("needs >1 device")
    from repro.compat import make_mesh
    mesh = make_mesh((jax.device_count(),), ("data",))

    def f(x):
        return jnp.sum(x)                 # all-reduce over data

    with mesh:
        c = jax.jit(f, in_shardings=NamedSharding(mesh, P("data"))).lower(
            jax.ShapeDtypeStruct((64,), jnp.float32)).compile()
    st = parse_hlo(c.as_text())
    assert st.total_collective_bytes > 0


def test_model_flops_6nd():
    st = param_stats("llama3-8b")
    assert 7.5e9 < st["total"] < 9e9          # ~8B
    mf = model_flops("llama3-8b", "train_4k")
    n = st["active"] - st["embed"]
    assert mf == 6.0 * n * 256 * 4096


def test_moe_active_params():
    st = param_stats("phi3.5-moe-42b-a6.6b")
    assert st["active"] < st["total"] / 2     # top-2 of 16 experts
    assert 35e9 < st["total"] < 50e9


def test_analyze_cell_int8_companion_terms():
    """The int8 twin of each roofline cell: matmuls at the doubled MXU
    peak, the weights-read HBM component at ~1/4 bytes, both arithmetic
    intensities populated (int8 strictly higher — same FLOPs over fewer
    bytes)."""
    import dataclasses

    from repro.analysis import hw
    from repro.analysis.analytic import analytic_cost
    from repro.analysis.roofline import analyze_cell

    n = 64
    c = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((n, n), jnp.float32)).compile()
    rep = analyze_cell("llama3-8b", "train_4k", "pod", 512, c)

    np.testing.assert_allclose(rep.compute_s_int8, rep.compute_s / 2.0,
                               rtol=1e-12)
    an = analytic_cost("llama3-8b", "train_4k", 512, rep.n_micro)
    w_read = an.components["weights_read"]
    assert w_read > 0
    np.testing.assert_allclose(
        rep.memory_s_int8,
        (an.hbm_bytes_per_device - 0.75 * w_read) / hw.HBM_BW, rtol=1e-12)
    assert rep.memory_s_int8 < rep.memory_s
    assert rep.arith_intensity_int8 > rep.arith_intensity > 0.0
    # the dry-run record schema: new fields serialize with the rest
    d = dataclasses.asdict(rep)
    for k in ("compute_s_int8", "memory_s_int8", "arith_intensity",
              "arith_intensity_int8"):
        assert k in d
