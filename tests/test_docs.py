"""Docs are executable: every fenced Python snippet in README.md and
``docs/*.md`` runs green, top to bottom, and every relative link (and
used anchor) resolves.

Contract:

* Each file's ``python`` fences execute sequentially in one shared
  namespace — later snippets may use names an earlier snippet defined,
  exactly as a reader following the page would.
* An HTML comment directly above a fence controls execution:
  ``<!-- docs-test: skip -->`` skips the block;
  ``<!-- docs-test: requires-devices=8 -->`` skips it unless
  ``jax.device_count()`` is at least that (the tier1-mesh CI job
  provides 8 simulated devices, so mesh snippets still execute there).
* Non-Python fences (``bash``, ASCII diagrams, JSON) are ignored.
* Snippets run with the repo root as cwd (some read committed files,
  e.g. ``BENCH_serving.json``).

A snippet that stops compiling or an API drift that breaks an example
fails this test — stale documentation is a CI failure, not a review
hope.
"""

from __future__ import annotations

import dataclasses
import os
import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
DOC_FILES = ["README.md"] + sorted(
    str(p.relative_to(REPO)) for p in (REPO / "docs").glob("*.md"))

_MARKER = re.compile(r"<!--\s*docs-test:\s*(.+?)\s*-->")


@dataclasses.dataclass
class Block:
    path: str
    lineno: int            # 1-based line of the opening fence
    code: str
    skip: bool = False
    requires_devices: int = 0


def extract_blocks(relpath: str) -> list[Block]:
    lines = (REPO / relpath).read_text().splitlines()
    blocks: list[Block] = []
    in_fence = False
    fence_lang = ""
    buf: list[str] = []
    start = 0
    for i, line in enumerate(lines, 1):
        stripped = line.strip()
        if not in_fence and stripped.startswith("```"):
            in_fence, fence_lang, buf, start = True, stripped[3:].strip(), [], i
        elif in_fence and stripped == "```":
            in_fence = False
            if fence_lang == "python":
                b = Block(relpath, start, "\n".join(buf) + "\n")
                # markers sit on the non-blank lines directly above
                j = start - 2
                while j >= 0 and (not lines[j].strip()
                                  or _MARKER.search(lines[j])):
                    m = _MARKER.search(lines[j])
                    if m:
                        directive = m.group(1)
                        if directive == "skip":
                            b.skip = True
                        elif directive.startswith("requires-devices="):
                            b.requires_devices = int(directive.split("=")[1])
                        else:
                            raise ValueError(
                                f"{relpath}:{j + 1}: unknown docs-test "
                                f"directive {directive!r}")
                    j -= 1
                blocks.append(b)
        elif in_fence:
            buf.append(line)
    assert not in_fence, f"{relpath}: unclosed code fence at line {start}"
    return blocks


@pytest.mark.parametrize("relpath", DOC_FILES)
def test_doc_snippets_execute(relpath):
    blocks = extract_blocks(relpath)
    assert blocks, f"{relpath} documents an executable API but has no " \
                   "python snippets"
    import jax
    ns: dict = {"__name__": f"docs_{Path(relpath).stem}"}
    old_cwd = os.getcwd()
    os.chdir(REPO)
    try:
        for b in blocks:
            if b.skip:
                continue
            if b.requires_devices and jax.device_count() < b.requires_devices:
                continue
            code = compile(b.code, f"{relpath}:{b.lineno}", "exec")
            exec(code, ns)      # noqa: S102 — executing our own docs is the point
    finally:
        os.chdir(old_cwd)


# --- links and anchors -------------------------------------------------------

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def _headings(relpath: Path) -> set[str]:
    """GitHub-style anchor slugs of every markdown heading in the file."""
    slugs = set()
    in_fence = False
    for line in relpath.read_text().splitlines():
        if line.strip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence or not line.startswith("#"):
            continue
        text = line.lstrip("#").strip()
        text = re.sub(r"`([^`]*)`", r"\1", text)        # drop code spans
        slug = re.sub(r"[^\w\- ]", "", text.lower()).replace(" ", "-")
        slugs.add(slug)
    return slugs


@pytest.mark.parametrize("relpath", DOC_FILES)
def test_doc_links_resolve(relpath):
    """Every relative link points at a real file, and every used anchor
    at a real heading — dead pointers (deleted files, renamed sections)
    fail here instead of rotting."""
    src = REPO / relpath
    problems = []
    in_fence = False
    for i, line in enumerate(src.read_text().splitlines(), 1):
        if line.strip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for target in _LINK.findall(line):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, anchor = target.partition("#")
            dest = (src.parent / path_part).resolve() if path_part else src
            if not dest.exists():
                problems.append(f"{relpath}:{i}: broken link {target!r}")
                continue
            if anchor and dest.suffix == ".md" \
                    and anchor not in _headings(dest):
                problems.append(f"{relpath}:{i}: dead anchor {target!r}")
    assert not problems, "\n".join(problems)


def test_every_doc_page_is_linked_from_readme():
    """docs/ pages that nothing references are unreachable documentation."""
    readme = (REPO / "README.md").read_text()
    for page in (REPO / "docs").glob("*.md"):
        assert f"docs/{page.name}" in readme, \
            f"docs/{page.name} is not linked from README.md"
