"""HostBackedStore tests — the out-of-HBM embedding tier (ISSUE-6).

Acceptance surface: ``HostBackedStore`` scores bit-exact with
``DenseStore`` (one-hot + multi-hot, uniform + zipf, before and after
``refresh()``, cold-cache miss storm, single-device and 8-way simulated
mesh) with **zero plan recompiles** across refreshes; a staging-buffer
overflow falls back to a synchronous chunked host gather instead of wrong
scores; the mmap third tier round-trips through ``backing_path=``/
``HostBackedStore.open``; and a vocab larger than the device-table budget
serves end-to-end through ``InferenceEngine.submit`` with the backing
never uploaded wholesale.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.compat import make_mesh
from repro.configs import ctr_spec
from repro.core import compile_plan
from repro.data.synthetic import CRITEO, zipf_ids
from repro.embedding import (DenseStore, FusedEmbeddingCollection,
                             FusedEmbeddingSpec, HostBackedStore,
                             PrefetchPipeline, StagingOverflowError)
from repro.models.ctr import CTR_MODELS
from repro.serving import FixedBatch, InferenceEngine

SPEC = FusedEmbeddingSpec(field_sizes=(60, 7, 350, 90), dim=8)
SCHEMA = CRITEO.scaled(2_000)
SPEC_KW = dict(embed_dim=8, hidden=64, max_field=2_000)


def needs(n):
    return pytest.mark.skipif(
        jax.device_count() < n,
        reason=f"needs {n} devices (run under XLA_FLAGS="
               "--xla_force_host_platform_device_count=8)")


def make_pair(capacity=48, staging_capacity=256, backing_path=None):
    """Dense and host-backed collections over the *same* table values."""
    dense = FusedEmbeddingCollection(SPEC)
    params_d = dense.init(jax.random.PRNGKey(0))
    store = HostBackedStore(SPEC, capacity=capacity,
                            staging_capacity=staging_capacity,
                            backing_path=backing_path)
    hosted = FusedEmbeddingCollection(SPEC, store=store)
    params_h = store.from_dense(params_d)
    return dense, params_d, hosted, params_h, store


def traffic(batch=128, exponent=None, seed=0):
    key = jax.random.PRNGKey(seed)
    if exponent is not None:
        return zipf_ids(key, batch, SPEC.field_sizes, exponent=exponent)
    rng = np.random.default_rng(seed)
    return jnp.asarray(np.stack([rng.integers(0, s, size=batch)
                                 for s in SPEC.field_sizes], axis=1),
                       dtype=jnp.int32)


def make_engine_pair(model_name="widedeep", capacity=64,
                     staging_capacity=256, batch=8, mesh=None):
    # separate model instances: use_store rebinds the model's collection
    spec = ctr_spec(model_name, "criteo", **SPEC_KW)
    dense_model = CTR_MODELS[model_name](spec)
    dense = InferenceEngine(dense_model,
                            dense_model.init(jax.random.PRNGKey(0)),
                            policy=FixedBatch(batch), mesh=mesh)
    model = CTR_MODELS[model_name](spec)
    params = model.init(jax.random.PRNGKey(0))
    store = HostBackedStore(spec.embedding_spec(), capacity=capacity,
                            staging_capacity=staging_capacity)
    eng = InferenceEngine(model, params, policy=FixedBatch(batch),
                          store=store, mesh=mesh)
    return dense, eng, store


def zipf_stream(n, seed=0, exponent=1.1):
    return np.asarray(zipf_ids(jax.random.PRNGKey(seed), n,
                               SCHEMA.field_sizes, exponent=exponent))


# --- bit-exactness ----------------------------------------------------------

@pytest.mark.parametrize("exponent", [None, 1.3])
def test_host_store_bit_exact_onehot(exponent):
    dense, pd, hosted, ph, store = make_pair()
    ids = traffic(exponent=exponent)
    ph = store.stage(ph, np.asarray(ids))        # resolve misses first
    want = np.asarray(dense.apply(pd, ids, strategy="jnp"))
    got = np.asarray(hosted.apply(ph, ids, strategy="jnp"))
    np.testing.assert_array_equal(got, want)
    # kernel-body validation of the Pallas three-level gather
    got_pl = np.asarray(hosted.apply(ph, ids[:16], strategy="pallas",
                                     interpret=True))
    np.testing.assert_array_equal(got_pl, want[:16])


@pytest.mark.parametrize("exponent", [None, 1.3])
def test_host_store_bit_exact_multihot(exponent):
    dense, pd, hosted, ph, store = make_pair()
    h = 3
    rng = np.random.default_rng(1)
    if exponent is None:
        ids = np.stack([rng.integers(0, s, size=(64, h))
                        for s in SPEC.field_sizes], axis=1)
    else:
        ids = np.stack([np.asarray(zipf_ids(jax.random.PRNGKey(t), 64,
                                            SPEC.field_sizes, exponent))
                        for t in range(h)], axis=-1)
    ids = jnp.asarray(ids, dtype=jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, size=ids.shape), jnp.float32)
    ph = store.stage(ph, np.asarray(ids), np.asarray(mask))
    want = np.asarray(dense.apply_multihot(pd, ids, mask, strategy="jnp"))
    got = np.asarray(hosted.apply_multihot(ph, ids, mask, strategy="jnp"))
    np.testing.assert_array_equal(got, want)
    want_pl = np.asarray(dense.apply_multihot(pd, ids[:8], mask[:8],
                                              strategy="pallas",
                                              interpret=True))
    got_pl = np.asarray(hosted.apply_multihot(ph, ids[:8], mask[:8],
                                              strategy="pallas",
                                              interpret=True))
    np.testing.assert_array_equal(got_pl, want_pl)


def test_host_store_bit_exact_after_refresh():
    dense, pd, hosted, ph, store = make_pair()
    ids = traffic(exponent=1.5)
    want = np.asarray(dense.apply(pd, ids, strategy="jnp"))
    hosted.observe(np.asarray(ids))
    ph = store.refresh(ph)
    ph = store.stage(ph, np.asarray(ids))
    got = np.asarray(hosted.apply(ph, ids, strategy="jnp"))
    np.testing.assert_array_equal(got, want)
    assert store.stats.refreshes == 1


def test_cold_cache_miss_storm_is_bit_exact():
    """Every row uncached (capacity 1, distinct uniform ids): the staging
    path alone must carry the whole batch, bit-exactly."""
    dense, pd, hosted, ph, store = make_pair(capacity=1,
                                             staging_capacity=SPEC.rows)
    ids = traffic(batch=48, seed=3)
    ph = store.stage(ph, np.asarray(ids))
    want = np.asarray(dense.apply(pd, ids, strategy="jnp"))
    got = np.asarray(hosted.apply(ph, ids, strategy="jnp"))
    np.testing.assert_array_equal(got, want)
    assert store.stats.staged_rows > 0
    assert store.stats.h2d_bytes == (store.stats.staged_rows * SPEC.dim
                                     * np.dtype(SPEC.dtype).itemsize)


def test_unstaged_miss_gathers_zero_guard():
    """The three-way select's guard: an unresolved row reads zero, never
    garbage (correctness then rests on the serve path staging first)."""
    _, _, hosted, ph, store = make_pair(capacity=4)
    ids = traffic(batch=8, seed=5)
    out = np.asarray(hosted.apply(ph, ids, strategy="jnp"))  # no stage()
    rows = np.asarray(ids) + SPEC.offsets[None, :]
    uncached = np.asarray(ph["slot_of_row"])[rows] < 0
    got = out.reshape(len(ids), SPEC.k, SPEC.dim)
    assert np.all(got[uncached] == 0.0)
    assert np.any(uncached)


# --- staging overflow -------------------------------------------------------

def test_stage_overflow_raises_not_wrong():
    _, _, _, ph, store = make_pair(capacity=1, staging_capacity=SPEC.k)
    ids = traffic(batch=64, seed=7)
    with pytest.raises(StagingOverflowError):
        store.stage(ph, np.asarray(ids))
    assert store.stats.staging_overflows == 1
    chunks = store.split_for_staging(np.asarray(ids))
    assert sum(len(c) for c in chunks) == 64
    for c in chunks:
        assert store.miss_rows(c).size <= store.staging_capacity


def test_engine_overflow_falls_back_to_chunked_serving():
    """A miss storm through a tiny staging buffer serves correct scores
    via the synchronous chunked host gather — slower, never wrong."""
    k = len(SCHEMA.field_sizes)
    dense, eng, store = make_engine_pair(capacity=8, staging_capacity=k)
    ids = zipf_stream(24, exponent=1.05)
    want = dense.predict(ids)
    eng.submit_many(list(ids))
    got = eng.serve_pending()
    np.testing.assert_array_equal(got, want)
    assert store.stats.staging_overflows > 0
    assert eng.stats.emb_staging_overflows == store.stats.staging_overflows


def test_staging_capacity_must_cover_one_sample():
    with pytest.raises(ValueError, match="staging_capacity"):
        HostBackedStore(SPEC, capacity=8, staging_capacity=SPEC.k - 1)


# --- prefetch pipeline ------------------------------------------------------

def test_prefetch_worker_resolves_hinted_misses():
    _, _, _, ph, store = make_pair(capacity=4, staging_capacity=128)
    ids = np.asarray(traffic(batch=16, seed=9))
    miss = store.miss_rows(ids)
    store.prefetch_hint(ids)
    assert store.pipeline.wait_idle(timeout=10.0)
    assert store.pipeline.staged_rows() >= min(miss.size, 128)
    # serve-time stage finds everything already resolved
    n0 = store.stats.staged_rows
    store.stage(ph, ids)
    assert store.stats.staged_rows == n0          # nothing left to gather
    assert store.stats.prefetched_rows >= miss.size


def test_refresh_promotes_hot_staged_rows_out_of_staging():
    _, _, hosted, ph, store = make_pair(capacity=4, staging_capacity=64)
    # ids whose global rows all miss the seeded cache (rows 0..3)
    hot = np.array([[7, 2, 17, 5]] * 50, dtype=np.int64)
    ph = store.stage(ph, hot)                     # hot rows enter staging
    hot_rows = hot[0] + SPEC.offsets
    assert np.all(np.asarray(
        store.pipeline.snapshot()[2][hot_rows] >= 0))
    hosted.observe(hot)
    ph = store.refresh(ph)
    # promoted into the cache tier...
    assert set(np.flatnonzero(np.asarray(ph["slot_of_row"]) >= 0)) \
        == set(hot_rows.tolist())
    # ...and evicted from staging (slots freed for cold rows)
    assert np.all(np.asarray(ph["staging_slot_of_row"])[hot_rows] < 0)


# --- mmap third tier --------------------------------------------------------

def test_mmap_backing_round_trip(tmp_path):
    path = tmp_path / "backing.npy"
    dense, pd, hosted, ph, store = make_pair(backing_path=path)
    assert isinstance(store.host_view(), np.memmap)
    ids = traffic(batch=32, seed=11)
    ph = store.stage(ph, np.asarray(ids))
    want = np.asarray(dense.apply(pd, ids, strategy="jnp"))
    np.testing.assert_array_equal(
        np.asarray(hosted.apply(ph, ids, strategy="jnp")), want)

    # reopen from disk — no table in RAM, values identical
    store2 = HostBackedStore.open(SPEC, capacity=48, backing_path=path,
                                  staging_capacity=256)
    hosted2 = FusedEmbeddingCollection(SPEC, store=store2)
    ph2 = store2.device_params()
    ph2 = store2.stage(ph2, np.asarray(ids))
    np.testing.assert_array_equal(
        np.asarray(hosted2.apply(ph2, ids, strategy="jnp")), want)
    np.testing.assert_array_equal(store2.host_view(), store.host_view())


# --- engine end-to-end ------------------------------------------------------

def test_engine_serves_bit_exact_with_zero_recompiles():
    dense, eng, store = make_engine_pair()
    ids = zipf_stream(40)
    want = dense.predict(ids)
    for wave in np.array_split(ids, 2):
        eng.submit_many(list(wave))
        eng.serve_pending()
        eng.refresh_cache()                       # swap mid-stream
    futs = eng.submit_many(list(ids))
    eng.flush()
    got = np.array([f.result(timeout=60.0) for f in futs])
    np.testing.assert_array_equal(got, want)
    assert store.stats.refreshes == 2
    assert eng.stats.cache_misses == 1            # compiled exactly once
    assert len(eng.cached_plans) == 1
    assert eng.stats.emb_staged_rows + eng.stats.emb_prefetched_rows > 0


def test_vocab_beyond_device_budget_serves_end_to_end():
    """The scale unlock: total rows exceed cache+staging, yet the engine
    serves through submit() with device-resident embedding bytes bounded
    by the cache+staging budget — the backing is never uploaded."""
    dense, eng, store = make_engine_pair(capacity=64, staging_capacity=256)
    spec = store.spec
    budget = ((store.capacity + store.staging_capacity) * spec.dim
              * np.dtype(spec.dtype).itemsize
              + 2 * spec.rows * 4)                # the two int32 maps
    assert spec.rows > store.capacity + store.staging_capacity
    ids = zipf_stream(30, seed=2)
    futs = eng.submit_many(list(ids))
    eng.flush()
    got = np.array([f.result(timeout=60.0) for f in futs])
    np.testing.assert_array_equal(got, dense.predict(ids))
    key = eng.model.main_embedding_key
    assert store.device_bytes(eng.params[key]) <= budget
    full_table = spec.rows * spec.dim * np.dtype(spec.dtype).itemsize
    assert (store.capacity + store.staging_capacity) * spec.dim * \
        np.dtype(spec.dtype).itemsize < full_table
    with pytest.raises(NotImplementedError):
        store.dense_view(eng.params[key])


# --- mesh (tier1-hostmem: XLA_FLAGS=--xla_force_host_platform_device_count=8)

@needs(8)
@pytest.mark.parametrize("shape,axes", [((2,), ("data",)),
                                        ((4, 2), ("data", "model"))])
def test_host_store_on_mesh_bit_exact_with_dense(shape, axes):
    """zipf traffic through a HostBackedStore engine on a real mesh equals
    the DenseStore engine on the same mesh bit-for-bit, pre and post
    refresh, with zero recompiles — backing host-side, all four device
    leaves replicated per partition_spec."""
    mesh = make_mesh(shape, axes)
    dense, eng, store = make_engine_pair(capacity=64, staging_capacity=256,
                                         mesh=mesh)
    ids = zipf_stream(24, exponent=1.05)
    want = dense.predict(ids)
    eng.submit_many(list(ids))
    np.testing.assert_array_equal(eng.serve_pending(), want)
    eng.refresh_cache()
    np.testing.assert_array_equal(eng.predict(ids), want)
    assert eng.stats.cache_misses == 1            # refresh never recompiled
    key = eng.model.main_embedding_key
    for leaf in store.runtime_keys:
        spec_t = tuple(eng.params[key][leaf].sharding.spec)
        assert all(ax is None for ax in spec_t), (leaf, spec_t)


@needs(8)
def test_host_partition_spec_replicates_all_device_leaves():
    spec = ctr_spec("dcnv2", "criteo", **SPEC_KW)
    store = HostBackedStore(spec.embedding_spec(), capacity=32)
    ps = store.partition_spec("model")
    assert set(ps) == set(store.runtime_keys)
    assert all(tuple(s) == () for s in ps.values())
