"""Serving-stack tests: batching policies, engine edge cases, plan cache.

Covers the ISSUE-1 acceptance surface: empty queue, partial batch below the
smallest bucket, ``allow_partial=False`` leaving the queue intact, submit-
order preservation across buckets, plan-cache hit/miss accounting, and a
mixed-size stream served through ≥2 distinct cached plans.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ctr_spec
from repro.data.synthetic import CRITEO
from repro.models.ctr import CTR_MODELS
from repro.serving import (BucketedBatch, FixedBatch, InferenceEngine,
                           TimeoutBatch)
from repro.serving.batching import BatchDecision

SCHEMA = CRITEO.scaled(2_000)
SPEC_KW = dict(embed_dim=8, hidden=64, max_field=2_000)


def make(model_name="widedeep"):
    spec = ctr_spec(model_name, "criteo", **SPEC_KW)
    model = CTR_MODELS[model_name](spec)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def rows_of(n, seed=0):
    rng = np.random.default_rng(seed)
    return [np.array([rng.integers(0, s) for s in SCHEMA.field_sizes],
                     dtype=np.int32) for _ in range(n)]


# --- batching policies (pure, no engine) ------------------------------------

def test_fixed_batch_policy():
    p = FixedBatch(32)
    assert p.buckets == (32,)
    assert p.decide(40, 0.0, allow_partial=False) == BatchDecision(32, 32)
    assert p.decide(8, 0.0, allow_partial=True) == BatchDecision(8, 32)
    assert p.decide(8, 0.0, allow_partial=False) is None
    assert p.decide(0, 0.0, allow_partial=True) is None


def test_bucketed_batch_prefers_largest_full_bucket():
    p = BucketedBatch((8, 16, 32))
    assert p.decide(100, 0.0, allow_partial=False) == BatchDecision(32, 32)
    assert p.decide(20, 0.0, allow_partial=False) == BatchDecision(16, 16)
    # below the smallest bucket: partial into the smallest shape only
    assert p.decide(3, 0.0, allow_partial=True) == BatchDecision(3, 8)
    assert p.decide(3, 0.0, allow_partial=False) is None


def test_bucketed_ladder_is_normalized():
    p = BucketedBatch((64, 8, 8, 32))
    assert p.ladder == (8, 32, 64)
    with pytest.raises(ValueError):
        BucketedBatch(())


def test_timeout_batch_gates_partials_on_wait():
    p = TimeoutBatch(FixedBatch(8), max_wait_ms=10.0)
    # full batches go immediately, even before the deadline
    assert p.decide(9, 0.0, allow_partial=True) == BatchDecision(8, 8)
    # partials wait out the deadline ...
    assert p.decide(3, 5.0, allow_partial=True) is None
    # ... then drain
    assert p.decide(3, 11.0, allow_partial=True) == BatchDecision(3, 8)
    # allow_partial=False still pins partials regardless of age
    assert p.decide(3, 99.0, allow_partial=False) is None


# --- engine edge cases -------------------------------------------------------

def test_empty_queue_serves_nothing():
    model, params = make()
    eng = InferenceEngine(model, params, policy=BucketedBatch((8, 16)))
    scores = eng.serve_pending()
    assert scores.shape == (0,)
    assert eng.stats.n_batches == 0 and eng.stats.n_requests == 0


def test_partial_below_smallest_bucket_pads_into_it():
    model, params = make()
    eng = InferenceEngine(model, params, policy=BucketedBatch((8, 16)))
    eng.submit_many(rows_of(3))
    scores = eng.serve_pending()
    assert scores.shape == (3,)
    assert eng.stats.batches_per_bucket == {8: 1}
    assert eng.stats.padded_rows_total == 5
    assert abs(eng.stats.padding_waste - 5 / 8) < 1e-9


def test_allow_partial_false_leaves_queue_intact():
    model, params = make()
    eng = InferenceEngine(model, params, policy=BucketedBatch((8, 16)))
    eng.submit_many(rows_of(5))
    scores = eng.serve_pending(allow_partial=False)
    assert scores.shape == (0,)
    assert eng.pending() == 5
    assert eng.stats.n_batches == 0
    # a later permissive drain serves exactly those 5, in order
    direct = np.asarray(model.predict_proba(
        params, jnp.asarray(np.stack(rows_of(5)))))
    np.testing.assert_allclose(eng.serve_pending(), direct,
                               rtol=1e-5, atol=1e-5)
    assert eng.pending() == 0


def test_submit_order_preserved_across_buckets():
    model, params = make()
    eng = InferenceEngine(model, params, policy=BucketedBatch((8, 16, 32)))
    rows = rows_of(43)
    eng.submit_many(rows)
    scores = eng.serve_pending()
    assert scores.shape == (43,)
    # 43 = 32-full + 8-full + 3 padded into 8: three batches, two shapes
    assert eng.stats.n_batches == 3
    assert eng.stats.batches_per_bucket == {32: 1, 8: 2}
    direct = np.asarray(model.predict_proba(params,
                                            jnp.asarray(np.stack(rows))))
    np.testing.assert_allclose(scores, direct, rtol=1e-5, atol=1e-5)


def test_plan_cache_hits_and_misses():
    model, params = make()
    eng = InferenceEngine(model, params, policy=BucketedBatch((8, 16)))
    eng.submit_many(rows_of(43))        # 16,16,8,3→8: buckets {16, 8}
    eng.serve_pending()
    assert eng.stats.cache_misses == 2
    assert len(eng.cached_plans) == 2
    assert set(eng.stats.compile_ms_per_bucket) == {8, 16}
    hits_before = eng.stats.cache_hits
    eng.submit_many(rows_of(43, seed=1))
    eng.serve_pending()
    assert eng.stats.cache_misses == 2          # nothing new compiled
    assert eng.stats.cache_hits > hits_before


def test_mixed_stream_through_multiple_cached_plans():
    """Acceptance: a mixed-size stream served via ≥2 distinct plans, scores
    matching the direct forward in submit order."""
    model, params = make("dcn")
    eng = InferenceEngine(model, params, policy=BucketedBatch((8, 16, 32)))
    all_rows, out = [], []
    for n in (12, 3, 40, 7):
        rows = rows_of(n, seed=n)
        all_rows += rows
        eng.submit_many(rows)
        out.append(eng.serve_pending())
    scores = np.concatenate(out)
    assert len(eng.cached_plans) >= 2
    direct = np.asarray(model.predict_proba(
        params, jnp.asarray(np.stack(all_rows))))
    np.testing.assert_allclose(scores, direct, rtol=1e-5, atol=1e-5)


def test_timeout_engine_holds_then_flushes():
    model, params = make()
    eng = InferenceEngine(
        model, params,
        policy=TimeoutBatch(FixedBatch(8), max_wait_ms=60_000.0))
    eng.submit_many(rows_of(3))
    assert eng.serve_pending().shape == (0,)    # inside the SLO window
    assert eng.pending() == 3
    scores = eng.flush()                        # force-drain overrides it
    assert scores.shape == (3,) and eng.pending() == 0


def test_one_shot_predict_reuses_cache():
    model, params = make()
    eng = InferenceEngine(model, params, policy=BucketedBatch((8, 16)))
    ids = np.stack(rows_of(5))
    scores = eng.predict(ids)
    assert scores.shape == (5,)
    assert eng.stats.cache_misses == 1          # covering bucket 8
    eng.predict(ids[0])                         # single row, same bucket
    assert eng.stats.cache_misses == 1


def test_one_shot_predict_chunks_oversize_batches():
    """Batches beyond the largest bucket chunk through it — the plan cache
    stays bounded by the policy's bucket set."""
    model, params = make()
    eng = InferenceEngine(model, params, policy=BucketedBatch((8, 16)))
    rows = rows_of(37)                           # 16 + 16 + 5→8
    scores = eng.predict(np.stack(rows))
    assert scores.shape == (37,)
    assert set(eng.stats.compile_ms_per_bucket) <= {8, 16}
    direct = np.asarray(model.predict_proba(
        params, jnp.asarray(np.stack(rows))))
    np.testing.assert_allclose(scores, direct, rtol=1e-5, atol=1e-5)


def test_fixed_batch_engine_serves():
    # the surface that replaced the removed CTRServingEngine shim
    model, params = make()
    eng = InferenceEngine(model, params, policy=FixedBatch(32), level="dual")
    eng.warmup()
    rows = rows_of(50)
    eng.submit_many(rows)
    scores = eng.serve_pending()
    assert scores.shape == (50,)
    assert eng.stats.n_batches == 2             # 32 full + 18 padded


# --- bounded latency window (ISSUE-2 satellite) ------------------------------

def test_latency_window_is_bounded():
    """p50/p99 are rolling-window percentiles; the sample buffer must not
    grow without bound under sustained traffic."""
    model, params = make()
    eng = InferenceEngine(model, params, policy=FixedBatch(8),
                          latency_window=16)
    for _ in range(6):
        eng.submit_many(rows_of(8))
        eng.serve_pending()
    assert eng.stats.n_requests == 48            # lifetime totals stay exact
    assert len(eng.stats.latency_ms) == 16       # window stays bounded
    assert eng.stats.p99_ms >= eng.stats.p50_ms >= 0.0


# --- embedding-store plumbing ------------------------------------------------

def test_engine_with_cached_store_matches_dense():
    from repro.embedding import CachedStore
    model, params = make()
    eng_d = InferenceEngine(model, params, policy=BucketedBatch((8, 16)))
    rows = rows_of(21)
    eng_d.submit_many(rows)
    want = eng_d.serve_pending()

    model_c, params_c = make()
    store = CachedStore(model_c.spec.embedding_spec(), capacity=256)
    eng_c = InferenceEngine(model_c, params_c,
                            policy=BucketedBatch((8, 16)), store=store)
    eng_c.submit_many(rows)
    got = eng_c.serve_pending()
    np.testing.assert_array_equal(got, want)
    st = eng_c.stats
    assert st.emb_cache_hits + st.emb_cache_misses \
        == 21 * model_c.spec.k                   # every served row observed
    assert eng_c.store is store
    # dense engine never counts embedding-cache traffic
    assert eng_d.stats.emb_cache_hits == eng_d.stats.emb_cache_misses == 0


def test_engine_refresh_cache_preserves_plans_and_stays_exact():
    """A refresh is a double-buffered tensor swap: the store tensors are
    runtime inputs of every compiled plan, so the plan cache survives."""
    from repro.embedding import CachedStore
    model, params = make()
    direct = InferenceEngine(model, params, policy=FixedBatch(8))
    rows = rows_of(16, seed=3)
    want = direct.predict(np.stack(rows))

    model_c, params_c = make()
    store = CachedStore(model_c.spec.embedding_spec(), capacity=64)
    eng = InferenceEngine(model_c, params_c, policy=FixedBatch(8),
                          store=store)
    got0 = eng.predict(np.stack(rows))
    keys0 = eng.cached_plans
    assert len(keys0) == 1
    eng.refresh_cache()
    assert eng.cached_plans == keys0             # plans survive the swap
    assert eng.stats.emb_cache_refreshes == 1
    got1 = eng.predict(np.stack(rows))           # no recompile, same scores
    assert eng.stats.cache_misses == 1
    np.testing.assert_array_equal(got0, got1)
    np.testing.assert_array_equal(got1, want)


def test_engine_auto_refresh_every_n_batches():
    from repro.embedding import CachedStore
    model, params = make()
    store = CachedStore(model.spec.embedding_spec(), capacity=64)
    eng = InferenceEngine(model, params, policy=FixedBatch(8),
                          store=store, refresh_every=2)
    for _ in range(4):
        eng.submit_many(rows_of(8))
        eng.serve_pending()
    assert store.stats.refreshes == 2            # batches 2 and 4
    assert eng.stats.emb_cache_refreshes == 2


def test_predict_chunking_through_cached_store():
    """Oversize one-shot batches chunk through the largest bucket with the
    tiered store in the loop — scores stay bit-exact with the dense path."""
    from repro.embedding import CachedStore
    model, params = make()
    dense_eng = InferenceEngine(model, params, policy=BucketedBatch((8, 16)))
    rows = np.stack(rows_of(37, seed=9))         # > largest bucket
    want = dense_eng.predict(rows)

    model_c, params_c = make()
    eng = InferenceEngine(model_c, params_c, policy=BucketedBatch((8, 16)),
                          store=CachedStore(model_c.spec.embedding_spec(),
                                            capacity=128))
    got = eng.predict(rows)
    assert got.shape == (37,)
    assert set(b for _, _, b in
               [(k.model, k.level, k.batch_size) for k in eng.cached_plans]) \
        <= {8, 16}
    np.testing.assert_array_equal(got, want)


# --- backpressure (max_queue_depth) ------------------------------------------

def test_submit_backpressure_rejects_beyond_max_queue_depth():
    from repro.serving import QueueFullError
    model, params = make()
    eng = InferenceEngine(model, params, policy=BucketedBatch((8,)),
                          max_queue_depth=4)
    futs = eng.submit_many(rows_of(7))
    rejected = [f for f in futs if f.done()]
    assert len(rejected) == 3 and eng.stats.n_rejected == 3
    for f in rejected:
        with pytest.raises(QueueFullError):
            f.result(timeout=0.1)
    # the accepted 4 still serve, in submit order, unaffected
    scores = eng.flush()
    assert scores.shape == (4,)
    accepted = [f for f in futs if f not in rejected]
    np.testing.assert_allclose([f.result(timeout=5.0) for f in accepted],
                               scores, rtol=1e-6)
    assert eng.stats.n_requests == 4


def test_submit_backpressure_reopens_after_drain():
    model, params = make()
    eng = InferenceEngine(model, params, policy=BucketedBatch((8,)),
                          max_queue_depth=2)
    eng.submit_many(rows_of(2))
    assert eng.submit(rows_of(1)[0]).done()          # full -> rejected
    eng.flush()                                       # drains the queue
    fut = eng.submit(rows_of(1)[0])                   # accepted again
    assert not fut.done()
    scores = eng.flush()
    assert scores.shape == (1,)
    assert fut.result(timeout=5.0) == pytest.approx(float(scores[0]))
    assert eng.stats.n_rejected == 1


def test_backpressure_default_is_unbounded():
    model, params = make()
    eng = InferenceEngine(model, params, policy=BucketedBatch((8,)))
    futs = eng.submit_many(rows_of(40))
    assert not any(f.done() for f in futs)
    eng.flush()
    assert eng.stats.n_rejected == 0 and eng.stats.n_requests == 40
