"""Multi-device test bodies (run in a subprocess with 8 host devices)."""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import numpy as np
import jax
import jax.numpy as jnp


def sharded_lookup():
    """Vocab-parallel fused lookup == replicated lookup."""
    from repro.core import FusedEmbeddingCollection, FusedEmbeddingSpec
    from repro.launch.mesh import make_test_mesh
    mesh = make_test_mesh(2, 4)
    spec = FusedEmbeddingSpec(field_sizes=(7, 30, 3, 12), dim=8,
                              pad_rows_to=4)
    emb = FusedEmbeddingCollection(spec)
    params = emb.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    ids = jnp.asarray(np.stack([rng.integers(0, n, size=16)
                                for n in spec.field_sizes], axis=1),
                      dtype=jnp.int32)
    want = emb.apply(params, ids, strategy="jnp")
    with mesh:
        got = jax.jit(lambda p, i: emb.apply_sharded(p, i, mesh))(params, ids)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def compressed_psum():
    from repro.launch.mesh import make_test_mesh
    from repro.training.compression import make_compressed_dp_step
    mesh = make_test_mesh(4, 2)

    def loss_fn(params, batch):
        return jnp.mean((batch["x"] @ params["w"] - batch["y"]) ** 2)

    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 4))}
    batch = {"x": jax.random.normal(jax.random.PRNGKey(1), (16, 8)),
             "y": jax.random.normal(jax.random.PRNGKey(2), (16, 4))}
    step = jax.jit(make_compressed_dp_step(loss_fn, mesh))
    with mesh:
        loss_c, grads_c = step(params, batch)
    loss_e, grads_e = jax.value_and_grad(loss_fn)(params, batch)
    assert abs(float(loss_c) - float(loss_e)) < 1e-5
    rel = (np.abs(np.asarray(grads_c["w"]) - np.asarray(grads_e["w"])).max()
           / np.abs(np.asarray(grads_e["w"])).max())
    assert rel < 0.02, rel


def flash_decode():
    """Distributed flash-decode == single-device decode."""
    from repro.launch.mesh import make_test_mesh
    from repro.models.lm import layers as L
    from repro.models.lm.config import LMConfig
    from repro.models.lm.transformer import DenseTransformer
    mesh = make_test_mesh(2, 4)
    cfg = LMConfig(name="t", family="dense", n_layers=2, d_model=32,
                   n_heads=4, n_kv_heads=2, d_ff=64, vocab=128,
                   dtype="float32", remat=False)
    m = DenseTransformer(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, 128)
    cache = m.init_cache(4, 16)
    lp, cache = m.prefill(params, toks, cache)
    nxt = jnp.argmax(lp, -1)[:, None].astype(toks.dtype)
    ref, _ = m.decode_step(params, nxt, cache)
    m.decode_ctx = L.DecodeShardCtx(mesh=mesh, batch_axes="data",
                                    seq_axis="model")
    with mesh:
        got, _ = jax.jit(m.decode_step)(params, nxt, cache)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def param_specs():
    """Every assigned arch gets a complete, divisibility-fitted spec tree."""
    from repro.configs import ARCH_NAMES, get_config
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_test_mesh
    from repro.models.lm import make_lm_model
    mesh = make_test_mesh(2, 4)
    for arch in ARCH_NAMES:
        cfg = get_config(arch).reduced()
        model = make_lm_model(cfg)
        shapes = jax.eval_shape(lambda m=model: m.init(jax.random.PRNGKey(0)))
        specs = shd.fit_spec_tree(
            mesh, shd.param_specs(cfg.family, shapes, cfg), shapes)
        n_sharded = sum(
            1 for s in jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(
                    x, jax.sharding.PartitionSpec))
            if any(a is not None for a in s))
        assert n_sharded > 0, arch


def cell_lowering():
    """A reduced cell lowers + compiles on the test mesh for all 3 kinds."""
    import dataclasses
    import repro.configs as C
    import repro.configs.qwen3_4b as mod
    mod.CONFIG = mod.CONFIG.reduced(qk_norm=True)
    from repro.launch.mesh import make_test_mesh
    from repro.launch.steps import build_cell
    mesh = make_test_mesh(2, 4)
    for shape, kind in [("train_4k", "train"), ("prefill_32k", "prefill"),
                        ("decode_32k", "decode")]:
        C.SHAPES[shape] = C.ShapeCell(shape, 64, 8, kind)
        cell = build_cell("qwen3-4b", shape, mesh)
        compiled = cell.lower()[0].compile()
        from repro.compat import cost_analysis
        assert cost_analysis(compiled)["flops"] > 0


if __name__ == "__main__":
    case = sys.argv[1]
    globals()[case]()
    print(f"{case} OK")
