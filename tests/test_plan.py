"""compile_plan / InferencePlan tests + the branch-order determinism fix.

The Fig.-8 acceptance property (all four levels identical through the new
API) lives here; the engine-level serving behaviour is in test_serving.py.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.compat import make_mesh
from repro.configs import ctr_spec
from repro.core import LEVELS, DualParallelExecutor, Op, compile_plan
from repro.core.scheduler import breadth_first_schedule
from repro.data.synthetic import CRITEO, synthetic_batch

SCHEMA = CRITEO.scaled(2_000)
SPEC_KW = dict(embed_dim=8, hidden=64, max_field=2_000)


def make(model_name="dcnv2"):
    from repro.models.ctr import CTR_MODELS
    spec = ctr_spec(model_name, "criteo", **SPEC_KW)
    model = CTR_MODELS[model_name](spec)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_all_levels_identical_through_plans():
    model, params = make()
    ids = synthetic_batch(SCHEMA, 0, 32)["ids"]
    outs = {level: compile_plan(model, params, level, 32).predict(
        np.asarray(ids)) for level in LEVELS}
    for level, out in outs.items():
        np.testing.assert_allclose(out, outs["naive"], rtol=1e-5, atol=1e-6,
                                   err_msg=level)


def test_plan_captures_schedule_and_stats():
    model, params = make()
    plan = compile_plan(model, params, "dual", 16)
    assert plan.stats.schedule_policy == "breadth_first"
    assert plan.batch_size == 16 and plan.level == "dual"
    assert plan.graph.is_valid_order(list(plan.order))
    assert plan.compile_ms > 0
    assert plan.key.model == model.spec.name


def test_plan_predict_pads_and_rejects_oversize():
    model, params = make()
    plan = compile_plan(model, params, "dual", 8)
    ids = np.asarray(synthetic_batch(SCHEMA, 0, 8)["ids"])
    full = plan.predict(ids)
    np.testing.assert_allclose(plan.predict(ids[:3]), full[:3],
                               rtol=1e-6, atol=1e-6)
    one = plan.predict(ids[0])                   # (k,) row accepted
    np.testing.assert_allclose(one, full[:1], rtol=1e-6, atol=1e-6)
    with pytest.raises(ValueError):
        plan.predict(np.concatenate([ids, ids]))


def test_plan_invalid_level_and_order_rejected():
    model, params = make()
    with pytest.raises(ValueError):
        compile_plan(model, params, "warp", 8)
    with pytest.raises(ValueError):
        compile_plan(model, params, "dual", 8, branch_order="sideways")


def test_plan_with_mesh_matches_unsharded():
    model, params = make()
    ids = np.asarray(synthetic_batch(SCHEMA, 0, 16)["ids"])
    want = compile_plan(model, params, "dual", 16).predict(ids)
    mesh = make_mesh((1, 1), ("data", "model"))
    got = compile_plan(model, params, "dual", 16, mesh=mesh).predict(ids)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    key = compile_plan(model, params, "dual", 16, mesh=mesh).key
    assert key.sharded


def test_model_compile_convenience():
    model, params = make("dcn")
    plan = model.compile(params, batch_size=8)
    ids = np.asarray(synthetic_batch(SCHEMA, 0, 8)["ids"])
    direct = np.asarray(model.predict_proba(params, jnp.asarray(ids)))
    np.testing.assert_allclose(plan.predict(ids), direct,
                               rtol=1e-5, atol=1e-5)


# --- branch-order determinism (ISSUE-1 satellite) ----------------------------

def _ops(prefix, n, module):
    return [Op(f"{prefix}{i}", lambda x: x, ("in",), f"{prefix}o{i}",
               module=module) for i in range(n)]


@pytest.mark.parametrize("ne,ni", [(3, 3), (2, 4), (4, 2)])
def test_forced_branch_order_is_deterministic(ne, ni):
    """"explicit"/"implicit" head choices hold for ANY branch lengths —
    including the equal-length case the old derivation silently lost."""
    explicit, implicit = _ops("e", ne, "explicit"), _ops("i", ni, "implicit")
    for first, head in (("explicit", "e"), ("implicit", "i")):
        q = breadth_first_schedule(explicit, implicit, first=first).queue
        assert q[0][0] == head, (first, q)


def test_longer_first_ties_go_to_explicit():
    explicit, implicit = _ops("e", 3, "explicit"), _ops("i", 3, "implicit")
    q = breadth_first_schedule(explicit, implicit).queue
    assert q[0][0] == "e"


def test_executor_branch_order_equal_length_branches():
    """End-to-end: a model whose branches tie must still honor the forced
    orders (widedeep's wide/deep branches are short enough to tie under
    fusion — we assert on whatever the model gives us plus a synthetic
    tie via the scheduler API above)."""
    model, params = make("dcnv2")
    heads = {}
    for order in ("explicit_first", "implicit_first"):
        ex = DualParallelExecutor(model.build_graph, level="dual",
                                  branch_order=order)
        graph, _ = ex.prepare(params)
        heads[order] = graph.op(ex.stats.queue[0]).module
    assert heads == {"explicit_first": "explicit",
                     "implicit_first": "implicit"}
    with pytest.raises(ValueError):
        DualParallelExecutor(model.build_graph, branch_order="random")
