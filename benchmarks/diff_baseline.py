"""Structural perf-trajectory diff: fresh ``run.py --json`` vs a committed
baseline.

    PYTHONPATH=src python -m benchmarks.run --dry --only embedding_host \\
        --json fresh.json
    python -m benchmarks.diff_baseline BENCH_embedding.json fresh.json

CPU timings are noise-bound in CI, so the committed baseline
(``BENCH_embedding.json``) pins only each cell's ``structural`` sub-dict —
counters that are deterministic for fixed traffic (hit rates, resolved
rows, byte budgets, assertion outcomes). This tool compares exactly those:
every suite cell carrying a ``structural`` key must match the baseline
field-for-field, and the cell sets must agree. Timing fields are ignored.

Exit 0 when the structural trajectory is unchanged; exit 1 with a
field-level report otherwise — an intentional change means regenerating
and committing the baseline alongside the code change.
"""

from __future__ import annotations

import argparse
import json
import sys


def _structural_cells(doc: dict) -> dict:
    """``{suite/cell: structural_dict}`` for every cell that pins one."""
    out = {}
    for suite, cells in doc.get("results", {}).items():
        if not isinstance(cells, dict):
            continue
        for cell, payload in cells.items():
            if isinstance(payload, dict) and "structural" in payload:
                out[f"{suite}/{cell}"] = payload["structural"]
    return out


def diff(baseline: dict, fresh: dict) -> list[str]:
    base, new = _structural_cells(baseline), _structural_cells(fresh)
    # scope the comparison to suites the baseline actually pins: each
    # committed baseline (BENCH_embedding.json, BENCH_mlp.json, ...) owns
    # its suites, and a full `run.py --json` dump carries every suite's
    # cells — without this, each baseline would reject the others' cells
    # as "absent from baseline"
    suites = {name.split("/", 1)[0] for name in base}
    new = {name: s for name, s in new.items()
           if name.split("/", 1)[0] in suites}
    problems = []
    for name in sorted(set(base) - set(new)):
        problems.append(f"{name}: cell missing from fresh run")
    for name in sorted(set(new) - set(base)):
        problems.append(f"{name}: new cell absent from baseline "
                        "(regenerate the baseline to admit it)")
    for name in sorted(set(base) & set(new)):
        b, f = base[name], new[name]
        for field in sorted(set(b) | set(f)):
            if b.get(field) != f.get(field):
                problems.append(f"{name}.{field}: baseline={b.get(field)!r} "
                                f"fresh={f.get(field)!r}")
    return problems


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("fresh", help="freshly generated run.py --json output")
    args = ap.parse_args()
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    n = len(_structural_cells(baseline))
    problems = diff(baseline, fresh)
    if problems:
        print(f"# structural drift vs {args.baseline} "
              f"({len(problems)} problem(s)):")
        for p in problems:
            print(f"  {p}")
        sys.exit(1)
    print(f"# structural trajectory unchanged ({n} cells vs "
          f"{args.baseline})")
    # surface the pinned bytes-moved ratios (quantized vs fp32 wire format)
    for name, s in sorted(_structural_cells(baseline).items()):
        ratios = {k: v for k, v in sorted(s.items())
                  if k.endswith("_ratio")}
        if ratios:
            print(f"# bytes-moved {name}: " +
                  ",".join(f"{k}={v}" for k, v in ratios.items()))


if __name__ == "__main__":
    main()
