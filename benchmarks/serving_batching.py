"""Serving-side batching-policy comparison — quantifying the bucketing win.

The same mixed-size request stream (bursts + stragglers, the shape of real
CTR traffic) is served through the InferenceEngine under each batching
policy; we report throughput, tail latency, padding waste (fraction of
device rows that were padding), and the number of compiled plans — the
trade the plan cache buys: a few extra compiles for strictly less padded
compute per request.
"""

from __future__ import annotations

import time

import numpy as np
import jax

from repro.configs import ctr_spec
from repro.data.synthetic import CRITEO
from repro.models.ctr import CTR_MODELS
from repro.serving import (BucketedBatch, FixedBatch, InferenceEngine,
                           TimeoutBatch)

from .common import emit

MAX_FIELD = 100_000
WAVES = (256, 512, 96, 130, 640, 70, 17, 256, 19, 4)   # 2000 requests


def _policies():
    ladder = (32, 64, 128, 256)
    return {
        "fixed256": FixedBatch(256),
        "bucketed": BucketedBatch(ladder),
        "timeout": TimeoutBatch(BucketedBatch(ladder), max_wait_ms=0.0),
    }


def run(quick: bool = False) -> dict:
    schema = CRITEO.scaled(MAX_FIELD)
    waves = WAVES[:4] if quick else WAVES
    results = {}
    for model_name in (["dcn"] if quick else list(CTR_MODELS)):
        spec = ctr_spec(model_name, "criteo", 16, 256, max_field=MAX_FIELD)
        model = CTR_MODELS[model_name](spec)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        stream = [[np.array([rng.integers(0, s)
                             for s in schema.field_sizes], dtype=np.int32)
                   for _ in range(n)] for n in waves]
        n_total = sum(len(w) for w in stream)
        for pname, policy in _policies().items():
            eng = InferenceEngine(model, params, level="dual", policy=policy)
            eng.warmup()
            t0 = time.perf_counter()
            for wave in stream:
                eng.submit_many(wave)
                eng.serve_pending()
            eng.flush()
            dt = time.perf_counter() - t0
            s = eng.stats
            emit(f"serving/{model_name}/{pname}", dt / n_total * 1e6,
                 f"req_s={n_total/dt:.0f} p99_ms={s.p99_ms:.1f} "
                 f"pad_waste={s.padding_waste:.3f} "
                 f"plans={len(eng.cached_plans)} batches={s.n_batches}")
            results[f"{model_name}/{pname}"] = (n_total / dt,
                                                s.padding_waste)
    return results


if __name__ == "__main__":
    run()
