"""Paper Fig. 7 / Table II — end-to-end inference speedup.

DPIFrame (level "dual": fused embedding + non-GEMM fusion + breadth-first
whole-graph program) vs the naive baseline (level "naive": per-field serial
lookups, op-by-op eager dispatch — the PyTorch-A analogue), on the same
backend, 4 models × {embed 16, 32} × {hidden 256, 512} × 2 datasets.
(The paper's 1024-wide config is dropped on CPU for wall-clock budget; the
trend is monotone in width.)
"""

from __future__ import annotations

import jax.numpy as jnp
import jax

from repro.configs import ctr_spec
from repro.core import compile_plan
from repro.data.synthetic import AVAZU, CRITEO, synthetic_batch
from repro.models.ctr import CTR_MODELS

from .common import emit, time_fn

BATCH = 2048
MAX_FIELD = 100_000     # paper Fig. 10(d): lookup cost is height-independent


def run(quick: bool = False) -> dict:
    datasets = {"criteo": CRITEO, "avazu": AVAZU}
    dims = [16] if quick else [16, 32]
    hiddens = [256] if quick else [256, 512]
    models = ["dcn"] if quick else list(CTR_MODELS)
    results = {}
    for ds_name, schema in (list(datasets.items())[:1] if quick
                            else datasets.items()):
        schema = schema.scaled(MAX_FIELD)
        batch = synthetic_batch(schema, 0, BATCH)
        for model_name in models:
            for d in dims:
                for h in hiddens:
                    spec = ctr_spec(model_name, ds_name, d, h,
                                    max_field=MAX_FIELD)
                    model = CTR_MODELS[model_name](spec)
                    params = model.init(jax.random.PRNGKey(0))
                    ids = batch["ids"]
                    t = {}
                    for level in ("naive", "dual"):
                        plan = compile_plan(model, params, level, BATCH)
                        t[level] = time_fn(plan.step, ids, reps=3, warmup=1)
                    sp = t["naive"] / t["dual"]
                    key = f"{model_name}_{ds_name}_{d}_{h}"
                    results[key] = sp
                    emit(f"e2e/{key}/naive", t["naive"])
                    emit(f"e2e/{key}/dpiframe", t["dual"],
                         f"speedup={sp:.2f}x")
    by_model = {}
    for k, v in results.items():
        by_model.setdefault(k.split("_")[0], []).append(v)
    for m, vals in by_model.items():
        emit(f"e2e/{m}/avg_speedup", 0.0,
             f"avg={sum(vals)/len(vals):.2f}x max={max(vals):.2f}x")
    return results


if __name__ == "__main__":
    run()
