"""Quantized-compute sweep: int8 MLP matmuls across the four CTR models.

The dense-branch counterpart of ``embedding_host``'s wire-format pair:
per model, compile the fp32 plan and the ``compute_dtype="int8"`` plan at
the same batch and pin the structural story of the quantized path —

  * **weight bytes**: the int8 plan's dense-branch weights shrink from
    ``4·fan_in·fan_out`` to ``fan_in·fan_out + 4·fan_out`` (int8 payload +
    per-output-channel fp32 scales). The ratio is **hard-asserted >= 3.5x**
    per model — the acceptance contract for this PR's bytes claim;
  * **plan coexistence**: both plans land in one engine-style cache under
    distinct ``PlanKey``s (``compute_dtype`` is part of plan identity), so
    a deployment can serve fp32 and int8 side by side;
  * **score sanity**: the int8 plan's scores stay within the model-level
    parity budget (|Δ| < 1e-2) of fp32 on the same batch — the trained
    gate lives in ``accuracy_parity --quant-mlp``; this is the untrained
    structural echo of it;
  * **refresh stays recompile-free**: an ``InferenceEngine`` serving the
    full stack (int8 ``CachedStore`` rows + int8 compute) takes a
    mid-stream ``refresh_cache()`` with ``cache_misses`` unchanged —
    weights are baked at plan compile, store rows are runtime inputs, and
    neither invalidates the other.

The returned dict separates ``structural`` (deterministic counters diffed
against the committed ``BENCH_mlp.json`` by ``benchmarks/diff_baseline``)
from noise-bound ``timing``.
"""

from __future__ import annotations

import numpy as np
import jax

from repro.configs import ctr_spec
from repro.core import compile_plan
from repro.data.synthetic import CRITEO, synthetic_batch
from repro.models.ctr import CTR_MODELS

from .common import emit, time_fn

RATIO_FLOOR = 3.5


def _plan_cell(model_name: str, vocab: int, batch: int, hidden: int) -> dict:
    spec = ctr_spec(model_name, "criteo", 16, hidden, max_field=vocab)
    model = CTR_MODELS[model_name](spec)
    params = model.init(jax.random.PRNGKey(0))
    ids = synthetic_batch(CRITEO.scaled(vocab), 7, batch)["ids"]

    plans = {}
    for dtype in ("fp32", "int8"):
        plans[dtype] = compile_plan(model, params, "dual", batch,
                                    compute_dtype=dtype)
    # compute_dtype is part of plan identity: the two plans must coexist
    # in any key-addressed cache, never alias
    keys = {dtype: p.key for dtype, p in plans.items()}
    assert keys["fp32"] != keys["int8"], keys

    scores = {dtype: np.asarray(p(ids)).reshape(-1)
              for dtype, p in plans.items()}
    d_score = float(np.abs(scores["fp32"] - scores["int8"]).max())
    assert d_score < 1e-2, (model_name, d_score)

    st = plans["int8"].stats
    q8_bytes = int(st.mlp_quant_weight_bytes)
    saved = int(st.mlp_quant_weight_bytes_saved)
    fp32_bytes = q8_bytes + saved           # saved = 4·in·out − q8 payload
    ratio = fp32_bytes / q8_bytes
    assert ratio >= RATIO_FLOOR, (model_name, ratio, fp32_bytes, q8_bytes)
    assert plans["fp32"].stats.mlp_quant_matmuls == 0

    us = {dtype: time_fn(p, ids, reps=3, warmup=1)
          for dtype, p in plans.items()}
    emit(f"mlp_quant/{model_name}/b{batch}/int8", us["int8"],
         f"fp32_us={us['fp32']:.1f},matmuls={st.mlp_quant_matmuls},"
         f"w_ratio={ratio:.2f},max|dscore|={d_score:.2e}")
    return {
        "structural": {
            "q8_matmuls": int(st.mlp_quant_matmuls),
            "q8_weight_bytes": q8_bytes,
            "q8_weight_bytes_saved": saved,
            "fp32_weight_bytes": fp32_bytes,
            "weight_bytes_ratio": round(ratio, 6),
            "plan_keys_distinct": True,     # asserted above
            "score_within_budget": True,    # asserted above (<1e-2)
        },
        "timing": {"fp32_us": us["fp32"], "int8_us": us["int8"],
                   "max_dscore": d_score},
    }


def _refresh_cell(model_name: str, vocab: int, batch: int, n: int) -> dict:
    """Full quantized stack under a mid-stream refresh: zero recompiles."""
    from repro.embedding import CachedStore
    from repro.serving import FixedBatch, InferenceEngine

    spec = ctr_spec(model_name, "criteo", 16, 256, max_field=vocab)
    model = CTR_MODELS[model_name](spec)
    params = model.init(jax.random.PRNGKey(0))
    store = CachedStore(spec.embedding_spec(), capacity=batch * 8,
                        row_dtype="int8")
    eng = InferenceEngine(model, params, policy=FixedBatch(batch),
                          store=store, compute_dtype="int8")
    ids = synthetic_batch(CRITEO.scaled(vocab), 11, n)["ids"]
    waves = np.array_split(np.asarray(ids), 2)

    eng.submit_many(list(waves[0]))
    eng.serve_pending()
    misses_before = eng.stats.cache_misses
    eng.refresh_cache()                     # double-buffered tensor swap
    eng.submit_many(list(waves[1]))
    eng.serve_pending()
    eng.flush()
    misses_after = eng.stats.cache_misses

    recompile_free = misses_after == misses_before
    assert recompile_free, (misses_before, misses_after)
    s = eng.stats
    emit(f"mlp_quant/{model_name}/refresh", 0.0,
         f"cache_misses={misses_after},refreshes={s.emb_cache_refreshes},"
         f"q8_matmuls={s.mlp_quant_matmuls},recompile_free={recompile_free}")
    return {
        "structural": {
            "cache_misses": int(misses_after),
            "refreshes": int(s.emb_cache_refreshes),
            "q8_matmuls": int(s.mlp_quant_matmuls),
            "q8_weight_bytes": int(s.mlp_quant_weight_bytes),
            "recompile_free": bool(recompile_free),
        },
        "timing": {"p50_ms": float(s.p50_ms), "p99_ms": float(s.p99_ms)},
    }


def run(quick: bool = False, dry: bool = False) -> dict:
    if dry:
        vocab, batch, n, hidden = 2_000, 8, 32, 64
        models = list(CTR_MODELS)
    elif quick:
        vocab, batch, n, hidden = 20_000, 32, 128, 128
        models = list(CTR_MODELS)
    else:
        vocab, batch, n, hidden = 100_000, 256, 1_024, 256
        models = list(CTR_MODELS)
    out = {}
    for name in models:
        out[f"{name}_plans"] = _plan_cell(name, vocab, batch, hidden)
    # one refresh cell is enough: the mechanism (baked weights vs runtime
    # store inputs) is model-agnostic
    out["refresh_int8_stack"] = _refresh_cell(models[0], vocab, batch, n)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--dry", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick, dry=args.dry)
