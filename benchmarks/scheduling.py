"""Paper Fig. 12/13 — stream-scheduling strategies.

Structural evidence (platform-independent): the breadth-first queue is a
valid topological order that interleaves the branches, so both branches'
first operators are issued within the first two launch slots — vs
depth-first where the second branch waits |branch1| slots. We report that
queue-position metric (the paper's "latency until both branches start", in
launch slots) plus CPU wall-time of the whole program for each policy and
each §V-H branch order.
"""

from __future__ import annotations

import jax

from repro.configs import ctr_spec
from repro.core import compile_plan
from repro.data.synthetic import CRITEO, synthetic_batch
from repro.models.ctr import CTR_MODELS

from .common import emit, time_fn

BATCH = 2048
MAX_FIELD = 100_000


def _slots_until_both(queue, graph_builder, params) -> int:
    """Launch slots until ops of BOTH branches have been issued."""
    g = graph_builder(params, "dual")
    mod = {op.name: op.module for op in g.ops}
    seen = set()
    for i, name in enumerate(queue):
        # fused names embed member ops; map via containment
        m = mod.get(name)
        if m is None:
            for op_name, op_mod in mod.items():
                if op_name in name:
                    m = op_mod
                    break
        if m in ("explicit", "implicit"):
            seen.add(m)
        if len(seen) == 2:
            return i + 1
    return len(queue)


def run(quick: bool = False) -> dict:
    schema = CRITEO.scaled(MAX_FIELD)
    batch = synthetic_batch(schema, 0, BATCH)
    results = {}
    for model_name in (["deepfm"] if quick else list(CTR_MODELS)):
        spec = ctr_spec(model_name, "criteo", 16, 512, max_field=MAX_FIELD)
        model = CTR_MODELS[model_name](spec)
        params = model.init(jax.random.PRNGKey(0))
        for policy, order in [("depth_first", "longer_first"),
                              ("breadth_first", "longer_first"),
                              ("breadth_first_A", "implicit_first"),
                              ("breadth_first_B", "explicit_first")]:
            level = "fused_all" if policy == "depth_first" else "dual"
            plan = compile_plan(model, params, level, BATCH,
                                branch_order=order)
            t = time_fn(plan.step, batch["ids"], reps=3, warmup=1)
            slots = _slots_until_both(plan.stats.queue, model.build_graph,
                                      params)
            emit(f"sched/{model_name}/{policy}", t,
                 f"slots_until_both_branches={slots}")
            results[f"{model_name}/{policy}"] = (t, slots)
    return results


if __name__ == "__main__":
    run()
