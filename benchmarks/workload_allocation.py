"""Paper Fig. 11 — output-first vs input-first workload allocation.

XLA-level analogues of the two kernels (timing Pallas interpret mode would
measure the Python emulator, not the algorithm): output-first = one
row-gather writing the (b, k·d) output directly; input-first = field-major
gather producing (k, b, d) + the reorganization transpose it then needs.
Numerical equality of the two layouts is asserted every run (the kernels
themselves are validated in tests/test_kernels.py).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import FusedEmbeddingCollection, FusedEmbeddingSpec

from .common import emit, time_fn


def run(quick: bool = False) -> dict:
    out = {}
    cases = ([(2048, 32)] if quick
             else [(2048, 32), (16384, 32), (65536, 32), (2048, 60)])
    for b, d in cases:
        k, n = 39, 100_000
        spec = FusedEmbeddingSpec(field_sizes=(n,) * k, dim=d)
        emb = FusedEmbeddingCollection(spec)
        params = emb.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        ids = jnp.asarray(rng.integers(0, n, size=(b, k)), dtype=jnp.int32)
        offs = jnp.asarray(spec.offsets)

        @jax.jit
        def output_first(table, ids):
            rows = (ids + offs[None, :]).reshape(-1)
            return jnp.take(table, rows, axis=0).reshape(b, k * d)

        @jax.jit
        def input_first(table, ids):
            rows_fmajor = (ids.T + offs[:, None]).reshape(-1)      # (k*b,)
            g = jnp.take(table, rows_fmajor, axis=0).reshape(k, b, d)
            return jnp.transpose(g, (1, 0, 2)).reshape(b, k * d)

        table = params["mega_table"]
        np.testing.assert_allclose(np.asarray(output_first(table, ids)),
                                   np.asarray(input_first(table, ids)),
                                   rtol=1e-6)
        t_of = time_fn(output_first, table, ids, reps=3, warmup=1)
        t_if = time_fn(input_first, table, ids, reps=3, warmup=1)
        tag = f"b{b}_d{d}"
        emit(f"alloc/{tag}/input_first", t_if)
        emit(f"alloc/{tag}/output_first", t_of, f"speedup={t_if/t_of:.2f}x")
        out[tag] = t_if / t_of
    return out


if __name__ == "__main__":
    run()
