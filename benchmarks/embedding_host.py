"""HostBackedStore sweep: vocab × capacity × skew, out-of-HBM serving.

The scale question behind the host tier (HugeCTR hierarchical parameter
server, arXiv:2210.08804): with only ``C`` cache rows and ``S`` staging
slots on device, how does the traffic skew govern the hit rate and the
host→device row traffic as the vocabulary grows past the device budget?

Per (vocab, capacity, skew) cell, a ``HostBackedStore`` engine and a
``DenseStore`` engine serve the *same* zipf stream (warm-up wave, one
mid-stream ``refresh_cache``, then the measured waves) and the cell
**hard-asserts** the acceptance contract rather than merely reporting it:

  * bit-exact scores vs the dense engine (``assert_array_equal``, not
    allclose), and
  * whenever ``rows > C + S``, device-resident embedding bytes stay within
    the cache + staging budget (``store.device_bytes``) — the backing is
    never uploaded wholesale.

CSV: ``emb_host/V{vocab}/C{cap}/{skew}/host`` with hit rate, resolved
(staged + prefetched) rows, h2d bytes per batch and p50/p99 in the derived
column. The returned dict separates a ``structural`` sub-dict — counters
that are deterministic for fixed traffic (hit rate, refreshes, overflows,
resolved rows, byte budgets, the assertion outcomes) — from noise-bound
``timing`` numbers; the committed ``BENCH_embedding.json`` baseline and
``benchmarks/diff_baseline.py`` compare only the structural part.

A final fp32-vs-int8 pair at d=32 (same stream, same capacity) pins the
quantized tier's bytes-moved claim: ``gather_bytes`` and
``resolved_wire_bytes`` must drop by >= 3.5x (exactly 128/36 B/row),
hard-asserted; the int8 cell's scores gate at ``atol=1e-2`` instead of
bit-exactness (the model-level contract is ``accuracy_parity --quant``).

Determinism notes baked into the protocol: the refresh happens only after
``pipeline.wait_idle()`` (no hint race across the epoch boundary), and the
staging buffer is sized above each cell's worst-case distinct miss set so
LRU evictions — whose order depends on which thread staged a row — never
fire. Within an epoch the *union* of resolved rows is then exactly the
distinct miss set, whichever side of the hint race resolves each row.
"""

from __future__ import annotations

import time

import numpy as np
import jax

from repro.configs import ctr_spec
from repro.data.synthetic import CRITEO, zipf_ids
from repro.embedding import HostBackedStore
from repro.models.ctr import CTR_MODELS
from repro.serving import FixedBatch, InferenceEngine

from .common import emit

MODEL = "widedeep"


def _stream(vocab: int, n: int, exponent: float, seed: int = 1):
    schema = CRITEO.scaled(vocab)
    return np.asarray(zipf_ids(jax.random.PRNGKey(seed), n,
                               schema.field_sizes, exponent=exponent))


def _build_pair(spec, capacity: int, staging: int, batch: int,
                row_dtype: str | None = None):
    # separate model instances: use_store rebinds the model's collection
    dense_model = CTR_MODELS[MODEL](spec)
    dense = InferenceEngine(dense_model,
                            dense_model.init(jax.random.PRNGKey(0)),
                            policy=FixedBatch(batch))
    model = CTR_MODELS[MODEL](spec)
    params = model.init(jax.random.PRNGKey(0))
    store = HostBackedStore(spec.embedding_spec(), capacity=capacity,
                            staging_capacity=staging, row_dtype=row_dtype)
    eng = InferenceEngine(model, params, policy=FixedBatch(batch),
                          store=store)
    return dense, eng, store


def _cell(vocab: int, capacity: int, exponent: float, n: int, batch: int,
          tag: str, *, dim: int = 16, row_dtype: str | None = None) -> dict:
    ids = _stream(vocab, n, exponent)
    spec = ctr_spec(MODEL, "criteo", dim, 256, max_field=vocab)
    emb = spec.embedding_spec()
    # staging must absorb the stream's full distinct row set so eviction
    # order (thread-dependent) never perturbs the structural counters
    distinct = np.unique(ids + emb.offsets[None, :]).size
    staging = int(min(distinct + batch * emb.k, emb.rows))
    dense, eng, store = _build_pair(spec, capacity, staging, batch, row_dtype)
    want = dense.predict(ids)

    waves = np.array_split(ids, 4)
    t0 = time.perf_counter()
    got = []
    for w, wave in enumerate(waves):
        eng.submit_many(list(wave))
        got.append(eng.serve_pending())
        if w == 1:                                # mid-stream cache rebuild
            store.pipeline.wait_idle(timeout=10.0)
            eng.refresh_cache()
    got.append(eng.flush())
    dt = time.perf_counter() - t0
    got = np.concatenate([g for g in got if g.size])

    # --- the acceptance contract, hard-asserted ---------------------------
    if row_dtype is None:
        np.testing.assert_array_equal(got, want)  # bit-exact, not allclose
    else:
        # int8 rows are lossy by design; here the contract is score parity
        # (the model-level gate lives in accuracy_parity --quant)
        np.testing.assert_allclose(got, want, rtol=0, atol=1e-2)
    st, es = store.stats, eng.stats
    key = eng.model.main_embedding_key
    dev_bytes = store.device_bytes(eng.params[key])
    row_bytes = store.wire_row_bytes              # dtype-aware (d+4 for int8)
    budget = ((store.capacity + store.staging_capacity) * row_bytes
              + 2 * store.spec.rows * 4)          # the two int32 maps
    out_of_hbm = store.spec.rows > store.capacity + store.staging_capacity
    if out_of_hbm:
        assert dev_bytes <= budget, (dev_bytes, budget)

    resolved = st.staged_rows + st.prefetched_rows
    n_batches = max(es.n_batches, 1)
    emit(f"emb_host/{tag}/host", dt / n * 1e6,
         f"hit_rate={es.emb_cache_hit_rate:.3f},resolved={resolved},"
         f"h2d_per_batch={st.h2d_bytes // n_batches}B,"
         f"p50={es.p50_ms:.1f}ms,p99={es.p99_ms:.1f}ms,"
         f"overflows={st.staging_overflows},out_of_hbm={out_of_hbm}")
    return {
        "structural": {
            "rows": int(store.spec.rows),
            "capacity": int(store.capacity),
            "staging_capacity": int(store.staging_capacity),
            "hit_rate": round(float(es.emb_cache_hit_rate), 6),
            "resolved_rows": int(resolved),
            "refreshes": int(st.refreshes),
            "overflows": int(st.staging_overflows),
            "device_bytes": int(dev_bytes),
            "budget_bytes": int(budget),
            "out_of_hbm": bool(out_of_hbm),
            "row_dtype": row_dtype or "fp32",
            "wire_row_bytes": int(store.wire_row_bytes),
            "gather_bytes": int(st.gather_bytes),
            "resolved_wire_bytes": int(resolved * store.wire_row_bytes),
            "bit_exact": row_dtype is None,       # the assert above gates us
        },
        "timing": {
            "us_per_req": dt / n * 1e6,
            "p50_ms": float(es.p50_ms),
            "p99_ms": float(es.p99_ms),
            "h2d_bytes": int(st.h2d_bytes),
            "staged_rows": int(st.staged_rows),
            "prefetched_rows": int(st.prefetched_rows),
        },
    }


def run(quick: bool = False, dry: bool = False) -> dict:
    if dry:
        n, batch = 48, 8
        vocabs, capacities, exponents = [2_000], [64], [1.05, 1.3]
    elif quick:
        n, batch = 200, 16
        vocabs, capacities = [20_000], [256, 2_048]
        exponents = [1.05, 1.3]
    else:
        n, batch = 1_000, 64
        vocabs, capacities = [100_000, 1_000_000], [4_096, 65_536]
        exponents = [1.05, 1.2, 1.4]
    out = {}
    for vocab in vocabs:
        for cap in capacities:
            for e in exponents:
                tag = f"V{vocab}/C{cap}/zipf{e}"
                out[f"V{vocab}_C{cap}_zipf{e}"] = _cell(
                    vocab, cap, e, n, batch, tag)

    # quantized wire-format pair: the same stream and capacity served at
    # d=32 with fp32 rows (128 B/row) vs int8+scale rows (36 B/row). Both
    # cells resolve the identical row set (tier choice is value-blind), so
    # the bytes-moved counters must show exactly 128/36 ~ 3.56x; the >=3.5x
    # floor is the acceptance contract, hard-asserted here.
    pv, pc, pe = vocabs[0], capacities[0], exponents[-1]
    pair = {}
    for rd in (None, "int8"):
        mode = rd or "fp32"
        pair[mode] = _cell(pv, pc, pe, n, batch,
                           f"V{pv}/C{pc}/zipf{pe}/d32/{mode}",
                           dim=32, row_dtype=rd)
        out[f"q8_pair_d32_{mode}"] = pair[mode]
    ratios = {}
    for key in ("gather_bytes", "resolved_wire_bytes"):
        f32b = pair["fp32"]["structural"][key]
        q8b = pair["int8"]["structural"][key]
        assert f32b / q8b >= 3.5, (key, f32b, q8b)
        ratios[f"{key}_ratio"] = round(f32b / q8b, 6)
    out["q8_pair_d32"] = {"structural": ratios}
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--dry", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick, dry=args.dry)
