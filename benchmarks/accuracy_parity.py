"""Paper Table I — accuracy parity: DPIFrame must not change the math.

Short-trains each CTR model on synthetic Criteo/Avazu, then evaluates
AUC/LogLoss with the naive executor and the full DPIFrame executor on a
held-out stream. The paper reports identity to ≥4 decimals; on one backend
our two paths are bit-identical, so we assert exact equality of scores and
report the metrics.

Quantized mode (``--quant`` / ``run_quant``): the int8 embedding tier is
deliberately *not* bit-exact, so its contract is this gate instead — for
every CTR model, serving the fp32-trained params through a
``row_dtype="int8"`` ``CachedStore`` must stay within **AUC delta < 1e-3
and per-score |Δ| < 1e-2** of the fp32 dense plan (DeepLight-style CTR
robustness to 8-bit rows). Hard-asserted; CI runs it in the tier1 matrix.

``--quant-mlp`` / ``run_quant_mlp`` stacks the other quantization half on
top: int8 rows *and* ``compute_dtype="int8"`` MLP matmuls together, same
budget, same hard asserts — the end-to-end contract for running fully
quantized in production.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ctr_spec
from repro.core import compile_plan
from repro.data.synthetic import AVAZU, CRITEO, synthetic_batch
from repro.models.ctr import CTR_MODELS
from repro.training.metrics import logloss, roc_auc
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update

from .common import emit

MAX_FIELD = 50_000


def _short_train(model, params, schema, steps=60, batch=512):
    cfg = AdamWConfig(lr=3e-3)
    state = adamw_init(params, cfg)

    @jax.jit
    def step_fn(state, batch):
        loss, grads = jax.value_and_grad(model.loss)(state.params, batch)
        state, m = adamw_update(state, grads, cfg)
        return state, loss

    for s in range(steps):
        state, loss = step_fn(state, synthetic_batch(schema, s, batch))
    return state.params


def run(quick: bool = False) -> dict:
    results = {}
    datasets = [("criteo", CRITEO)] if quick else [("avazu", AVAZU),
                                                   ("criteo", CRITEO)]
    models = ["dcn"] if quick else list(CTR_MODELS)
    for ds_name, schema in datasets:
        schema = schema.scaled(MAX_FIELD)
        val = synthetic_batch(schema, 10_000, 4096)
        for model_name in models:
            spec = ctr_spec(model_name, ds_name, 16, 128,
                            max_field=MAX_FIELD)
            model = CTR_MODELS[model_name](spec)
            params = model.init(jax.random.PRNGKey(0))
            params = _short_train(model, params, schema,
                                  steps=20 if quick else 60)
            scores = {}
            for level in ("naive", "dual"):
                plan = compile_plan(model, params, level,
                                    int(val["ids"].shape[0]))
                logits = np.asarray(plan(val["ids"])).reshape(-1)
                scores[level] = 1.0 / (1.0 + np.exp(-logits))
            # eager vs whole-graph are different XLA programs, so exact bit
            # equality is backend fusion-order luck; the paper's Table-I
            # claim is metric identity to >=4 (in fact 6) decimals.
            np.testing.assert_allclose(scores["naive"], scores["dual"],
                                       rtol=1e-5, atol=1e-6)
            labels = np.asarray(val["labels"])
            metrics = {}
            for level, sc in scores.items():
                metrics[level] = (roc_auc(labels, sc), logloss(labels, sc))
            d_auc = abs(metrics["naive"][0] - metrics["dual"][0])
            d_ll = abs(metrics["naive"][1] - metrics["dual"][1])
            assert d_auc < 1e-6 and d_ll < 1e-6, (d_auc, d_ll)
            auc, ll = metrics["dual"]
            emit(f"parity/{model_name}_{ds_name}", 0.0,
                 f"auc={auc:.4f} logloss={ll:.4f} "
                 f"dAUC={d_auc:.2e} dLL={d_ll:.2e}")
            results[f"{model_name}_{ds_name}"] = (auc, ll)
    return results


def run_quant(quick: bool = False) -> dict:
    """Accuracy-parity gate for the int8 embedding tier.

    For every CTR model: short-train fp32 params, score the held-out
    stream through the fp32 dense dual plan, then through the same params
    adopted into a ``row_dtype="int8"`` ``CachedStore``, and hard-assert
    AUC delta < 1e-3 and per-score |Δ| < 1e-2.
    """
    from repro.embedding import CachedStore

    results = {}
    schema = CRITEO.scaled(MAX_FIELD)
    val = synthetic_batch(schema, 10_000, 4096)
    labels = np.asarray(val["labels"])
    models = ["dcn"] if quick else list(CTR_MODELS)
    for model_name in models:
        spec = ctr_spec(model_name, "criteo", 16, 128, max_field=MAX_FIELD)
        model = CTR_MODELS[model_name](spec)
        params = model.init(jax.random.PRNGKey(0))
        params = _short_train(model, params, schema,
                              steps=20 if quick else 40)

        plan = compile_plan(model, params, "dual",
                            int(val["ids"].shape[0]))
        logits = np.asarray(plan(val["ids"])).reshape(-1)
        sc_fp32 = 1.0 / (1.0 + np.exp(-logits))

        qmodel = CTR_MODELS[model_name](spec)
        store = CachedStore(qmodel.spec.embedding_spec(), capacity=4096,
                            row_dtype="int8")
        qparams = qmodel.use_store(store, params)
        qplan = compile_plan(qmodel, qparams, "dual",
                             int(val["ids"].shape[0]))
        qlogits = np.asarray(qplan(val["ids"])).reshape(-1)
        sc_q8 = 1.0 / (1.0 + np.exp(-qlogits))

        auc_fp32 = roc_auc(labels, sc_fp32)
        auc_q8 = roc_auc(labels, sc_q8)
        d_auc = abs(auc_fp32 - auc_q8)
        d_score = float(np.abs(sc_fp32 - sc_q8).max())
        assert d_auc < 1e-3, (model_name, d_auc)
        assert d_score < 1e-2, (model_name, d_score)
        emit(f"parity_q8/{model_name}_criteo", 0.0,
             f"auc_fp32={auc_fp32:.4f} auc_int8={auc_q8:.4f} "
             f"dAUC={d_auc:.2e} max|dscore|={d_score:.2e}")
        results[f"{model_name}_criteo"] = (auc_fp32, auc_q8,
                                           d_auc, d_score)
    return results


def run_quant_mlp(quick: bool = False) -> dict:
    """Accuracy-parity gate for the *combined* quantization story.

    The harshest realistic configuration: int8 embedding rows (PR 7's
    store tier) **and** int8 MLP matmuls (``compute_dtype="int8"``)
    stacked, scored against the all-fp32 dense dual plan. For every CTR
    model: short-train fp32 params, then hard-assert AUC delta < 1e-3 and
    per-score |Δ| < 1e-2. Head and cross GEMMs stay fp32 by design, which
    is what keeps the stacked error inside the same budget as either
    half alone.
    """
    from repro.embedding import CachedStore

    results = {}
    schema = CRITEO.scaled(MAX_FIELD)
    val = synthetic_batch(schema, 10_000, 4096)
    labels = np.asarray(val["labels"])
    models = ["dcn"] if quick else list(CTR_MODELS)
    for model_name in models:
        spec = ctr_spec(model_name, "criteo", 16, 128, max_field=MAX_FIELD)
        model = CTR_MODELS[model_name](spec)
        params = model.init(jax.random.PRNGKey(0))
        params = _short_train(model, params, schema,
                              steps=20 if quick else 40)

        plan = compile_plan(model, params, "dual",
                            int(val["ids"].shape[0]))
        logits = np.asarray(plan(val["ids"])).reshape(-1)
        sc_fp32 = 1.0 / (1.0 + np.exp(-logits))

        qmodel = CTR_MODELS[model_name](spec)
        store = CachedStore(qmodel.spec.embedding_spec(), capacity=4096,
                            row_dtype="int8")
        qparams = qmodel.use_store(store, params)
        qplan = compile_plan(qmodel, qparams, "dual",
                             int(val["ids"].shape[0]),
                             compute_dtype="int8")
        qlogits = np.asarray(qplan(val["ids"])).reshape(-1)
        sc_q8 = 1.0 / (1.0 + np.exp(-qlogits))

        auc_fp32 = roc_auc(labels, sc_fp32)
        auc_q8 = roc_auc(labels, sc_q8)
        d_auc = abs(auc_fp32 - auc_q8)
        d_score = float(np.abs(sc_fp32 - sc_q8).max())
        assert d_auc < 1e-3, (model_name, d_auc)
        assert d_score < 1e-2, (model_name, d_score)
        emit(f"parity_q8mlp/{model_name}_criteo", 0.0,
             f"auc_fp32={auc_fp32:.4f} auc_int8={auc_q8:.4f} "
             f"dAUC={d_auc:.2e} max|dscore|={d_score:.2e}")
        results[f"{model_name}_criteo"] = (auc_fp32, auc_q8,
                                           d_auc, d_score)
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quant", action="store_true",
                    help="gate the int8 embedding tier against the fp32 "
                         "dense plan instead of naive-vs-dual parity")
    ap.add_argument("--quant-mlp", action="store_true",
                    help="gate int8 rows + int8 MLP matmuls stacked "
                         "against the all-fp32 dense plan")
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    fn = run_quant_mlp if a.quant_mlp else (run_quant if a.quant else run)
    fn(quick=a.quick)
