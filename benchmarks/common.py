"""Shared benchmark utilities: robust timing + CSV emission."""

from __future__ import annotations

import time

import numpy as np
import jax

__all__ = ["time_fn", "emit", "small_spec"]


def time_fn(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median wall-time (µs) of fn(*args), blocking on the result."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """The scaffold's required CSV contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


def small_spec(model_name: str, dataset: str = "criteo", embed_dim: int = 16,
               hidden: int = 256, max_field: int = 100_000):
    from repro.configs import ctr_spec
    return ctr_spec(model_name, dataset, embed_dim, hidden,
                    max_field=max_field)
