"""Async serving runtime benchmark — sync drain vs futures intake,
plan-cache survival across embedding-cache refreshes, and the many-model
shared-scheduler sweep.

Measurements on the same zipf request stream:

  1. **sync**: the caller submits a wave then drains it (`serve_pending`)
     — the pre-runtime serving loop, intake blocked on compute.
  2. **async**: the background worker drains the queue through the same
     policy while the caller keeps submitting; per-request futures
     resolve as batches complete (PCDF's full-link-parallel loop).
  3. **refresh survival**: a `CachedStore` engine refreshes its hot-row
     cache repeatedly under traffic; because the store tensors are
     runtime inputs of every compiled plan, the plan cache must survive
     each refresh with zero new compiles (`survived=True` in the derived
     column — the HugeCTR online-refresh property).
  4. **many-model sweep** (models × offered load): the same round-robin
     traffic served twice — through one shared ``DeviceScheduler`` pool
     and through per-engine worker threads. Reports p99, thread-count
     delta, and per-model device-time share; hard-asserts the shared
     mode's thread budget (≤ pool_size + 1 new threads however many
     models are hosted) and score bit-exactness across modes.

Throughput deltas on CPU are modest (compute dominates); the structural
counters (batches formed without caller polling, compiles across
refreshes, thread budgets, cross-mode exactness) are the point — each
sweep cell's ``structural`` sub-dict holds only traffic-deterministic
values and is pinned by ``BENCH_serving.json`` via
``benchmarks/diff_baseline.py`` (timing fields live in ``timing`` and are
ignored).
"""

from __future__ import annotations

import threading
import time

import numpy as np
import jax

from repro.configs import ctr_spec
from repro.data.synthetic import CRITEO, zipf_ids
from repro.embedding import CachedStore
from repro.models.ctr import CTR_MODELS
from repro.serving import (BucketedBatch, InferenceEngine, ServingRuntime,
                           TimeoutBatch)

from .common import emit

MAX_FIELD = 100_000


def _stream(schema, n, exponent=1.1, seed=0):
    return np.asarray(zipf_ids(jax.random.PRNGKey(seed), n,
                               schema.field_sizes, exponent=exponent))


def _build(model_name, max_field, store=None, **eng_kwargs):
    spec = ctr_spec(model_name, "criteo", 16, 256, max_field=max_field)
    model = CTR_MODELS[model_name](spec)
    params = model.init(jax.random.PRNGKey(0))
    return spec, InferenceEngine(model, params, store=store, **eng_kwargs)


def _sync(eng, ids, waves):
    t0 = time.perf_counter()
    for wave in np.array_split(ids, waves):
        eng.submit_many(list(wave))
        eng.serve_pending()
    eng.flush()
    return time.perf_counter() - t0


def _async(eng, ids):
    eng.start()
    t0 = time.perf_counter()
    futs = eng.submit_many(list(ids))
    for f in futs:
        f.result(timeout=300.0)
    dt = time.perf_counter() - t0
    eng.stop()
    return dt


def _sweep_cell(n_models: int, n_requests: int, ladder, max_field: int,
                pool_size: int = 2) -> dict:
    """One (models × offered load) cell: shared scheduler vs per-engine
    workers on identical traffic. Small dims (embed 8, hidden 64) keep
    the N-model compile cost bounded; the serving-loop behaviour under
    test doesn't depend on model width."""
    schema = CRITEO.scaled(max_field)
    ids = _stream(schema, n_requests, seed=1)

    def build_rt(mode):
        rt = ServingRuntime(scheduler=mode, pool_size=pool_size)
        for i in range(n_models):
            spec = ctr_spec("widedeep", "criteo", 8, 64,
                            max_field=max_field)
            model = CTR_MODELS["widedeep"](spec)
            rt.add_model(f"m{i}", model,
                         model.init(jax.random.PRNGKey(i)),
                         policy=TimeoutBatch(BucketedBatch(ladder),
                                             max_wait_ms=2.0),
                         worker_tick_ms=1.0)
        rt.warmup()
        return rt

    def drive(rt):
        t0 = time.perf_counter()
        futs = [rt.submit(rt.models[i % n_models], row)
                for i, row in enumerate(ids)]
        scores = np.array([f.result(timeout=600.0) for f in futs])
        return scores, time.perf_counter() - t0

    rt_s = build_rt("shared")
    before = threading.active_count()
    rt_s.start()
    scores_s, dt_s = drive(rt_s)
    delta_s = threading.active_count() - before
    rt_s.stop()
    agg_s = rt_s.stats()
    share_sum = sum(rt_s.scheduler.shares.values())
    shares = {n: round(s, 3) for n, s in sorted(
        rt_s.scheduler.shares.items())}

    rt_p = build_rt("per-engine")
    before = threading.active_count()
    rt_p.start()
    scores_p, dt_p = drive(rt_p)
    delta_p = threading.active_count() - before
    rt_p.stop()
    agg_p = rt_p.stats()

    # the acceptance property, asserted where the sweep runs (CI dry
    # included): thread count must not scale with model count
    assert delta_s <= pool_size + 1, (
        f"shared scheduler spawned {delta_s} threads for {n_models} "
        f"models; budget is pool_size + 1 = {pool_size + 1}")
    bitexact = bool(np.array_equal(scores_s, scores_p))
    tag = f"sweep_m{n_models}_r{n_requests}"
    emit(f"serving_async/{tag}/shared", dt_s / n_requests * 1e6,
         f"req_s={n_requests/dt_s:.0f} p99_ms={agg_s.p99_ms:.1f} "
         f"threads=+{delta_s} dispatches={agg_s.sched_dispatches} "
         f"bitexact={bitexact}")
    emit(f"serving_async/{tag}/per_engine", dt_p / n_requests * 1e6,
         f"req_s={n_requests/dt_p:.0f} p99_ms={agg_p.p99_ms:.1f} "
         f"threads=+{delta_p}")
    return {
        "structural": {
            # deterministic for fixed traffic: pinned by BENCH_serving.json
            "n_models": n_models,
            "n_requests_per_mode": int(agg_s.n_requests),
            "pool_size": pool_size,
            "thread_budget_ok": True,        # the assert above enforces it
            "bitexact_vs_per_engine": bitexact,
            "share_sum_ok": bool(abs(share_sum - 1.0) < 1e-6),
            "compiles_total": int(agg_s.cache_misses),
            "worker_errors": int(agg_s.n_worker_errors
                                 + agg_p.n_worker_errors),
        },
        "timing": {
            "p99_ms_shared": agg_s.p99_ms,
            "p99_ms_per_engine": agg_p.p99_ms,
            "req_s_shared": n_requests / dt_s,
            "req_s_per_engine": n_requests / dt_p,
            "threads_shared": delta_s,
            "threads_per_engine": delta_p,
            "sched_dispatches": int(agg_s.sched_dispatches),
            "preempted_slack_ms": agg_s.sched_preempted_slack_ms,
            "device_time_share": shares,
        },
    }


def run(quick: bool = False, dry: bool = False) -> dict:
    n = 64 if dry else (400 if quick else 2000)
    ladder = (8, 16) if dry else (32, 64, 128, 256)
    max_field = 2_000 if dry else MAX_FIELD
    models = ["widedeep"] if (dry or quick) else ["deepfm", "dcnv2"]
    schema = CRITEO.scaled(max_field)
    ids = _stream(schema, n)
    results = {}

    # --- sync drain vs async futures intake -------------------------------
    for model_name in models:
        policy = TimeoutBatch(BucketedBatch(ladder), max_wait_ms=1.0)
        _, eng_s = _build(model_name, max_field, policy=policy)
        eng_s.warmup()
        dt_s = _sync(eng_s, ids, waves=4 if dry else 10)
        _, eng_a = _build(model_name, max_field, policy=policy)
        eng_a.warmup()
        dt_a = _async(eng_a, ids)
        ss, sa = eng_s.stats, eng_a.stats
        emit(f"serving_async/{model_name}/sync", dt_s / n * 1e6,
             f"req_s={n/dt_s:.0f} p99_ms={ss.p99_ms:.1f} "
             f"batches={ss.n_batches}")
        emit(f"serving_async/{model_name}/async", dt_a / n * 1e6,
             f"req_s={n/dt_a:.0f} p99_ms={sa.p99_ms:.1f} "
             f"batches={sa.n_batches} worker_drained=True")
        results[f"{model_name}/speedup"] = dt_s / dt_a

    # --- refresh-without-recompile under zipf traffic ----------------------
    store = CachedStore(
        ctr_spec(models[0], "criteo", 16, 256,
                 max_field=max_field).embedding_spec(),
        capacity=max(64, max_field // 50))
    _, eng = _build(models[0], max_field, store=store,
                    policy=BucketedBatch(ladder),
                    refresh_every=2)                 # refresh every 2 batches
    eng.warmup()
    compiles_before = eng.stats.cache_misses
    plans_before = set(eng.cached_plans)
    for wave in np.array_split(ids, 4):
        eng.submit_many(list(wave))
        eng.serve_pending()
    eng.flush()
    st = eng.stats
    survived = (eng.stats.cache_misses == compiles_before
                and set(eng.cached_plans) == plans_before)
    emit(f"serving_async/{models[0]}/refresh_survival",
         st.compute_ms_total / max(st.n_batches, 1) * 1e3,
         f"refreshes={st.emb_cache_refreshes} "
         f"compiles={st.cache_misses} survived={survived} "
         f"emb_hit={st.emb_cache_hit_rate:.2f} "
         f"cached_traffic={st.emb_cached_traffic_fraction:.2f}")
    results["refresh_survived"] = survived

    # --- two-model runtime through one async intake -------------------------
    if not dry:
        rt = ServingRuntime()
        for m in (models if len(models) > 1 else models + ["dcn"]):
            spec = ctr_spec(m, "criteo", 16, 256, max_field=max_field)
            model = CTR_MODELS[m](spec)
            rt.add_model(m, model, model.init(jax.random.PRNGKey(0)),
                         policy=TimeoutBatch(BucketedBatch(ladder),
                                             max_wait_ms=1.0))
        rt.warmup()
        rt.start()
        t0 = time.perf_counter()
        futs = [rt.submit(rt.models[i % len(rt.models)], row)
                for i, row in enumerate(ids)]
        for f in futs:
            f.result(timeout=300.0)
        dt = time.perf_counter() - t0
        rt.stop()
        agg = rt.stats()
        emit("serving_async/runtime/2models", dt / n * 1e6,
             f"req_s={n/dt:.0f} p99_ms={agg.p99_ms:.1f} "
             f"models={agg.n_models} batches={agg.n_batches}")
        results["runtime/req_s"] = n / dt

    # --- many-model sweep: shared scheduler vs per-engine workers ----------
    # cell names are part of the pinned baseline: the CI dry run must
    # produce exactly the dry list below (diff_baseline compares cell sets)
    cells = ([(2, 64), (6, 96)] if dry else
             ([(4, 256)] if quick else [(8, 2000), (8, 8000)]))
    for n_models, n_requests in cells:
        results[f"sweep_m{n_models}_r{n_requests}"] = _sweep_cell(
            n_models, n_requests, ladder, max_field)
    return results


if __name__ == "__main__":
    run()
