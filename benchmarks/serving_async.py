"""Async serving runtime benchmark — sync drain vs futures intake, and
plan-cache survival across embedding-cache refreshes.

Three measurements on the same zipf request stream:

  1. **sync**: the caller submits a wave then drains it (`serve_pending`)
     — the pre-runtime serving loop, intake blocked on compute.
  2. **async**: the background worker drains the queue through the same
     policy while the caller keeps submitting; per-request futures
     resolve as batches complete (PCDF's full-link-parallel loop).
  3. **refresh survival**: a `CachedStore` engine refreshes its hot-row
     cache repeatedly under traffic; because the store tensors are
     runtime inputs of every compiled plan, the plan cache must survive
     each refresh with zero new compiles (`survived=True` in the derived
     column — the HugeCTR online-refresh property).

Throughput deltas on CPU are modest (compute dominates); the structural
counters (batches formed without caller polling, compiles across
refreshes) are the point.
"""

from __future__ import annotations

import time

import numpy as np
import jax

from repro.configs import ctr_spec
from repro.data.synthetic import CRITEO, zipf_ids
from repro.embedding import CachedStore
from repro.models.ctr import CTR_MODELS
from repro.serving import (BucketedBatch, InferenceEngine, ServingRuntime,
                           TimeoutBatch)

from .common import emit

MAX_FIELD = 100_000


def _stream(schema, n, exponent=1.1, seed=0):
    return np.asarray(zipf_ids(jax.random.PRNGKey(seed), n,
                               schema.field_sizes, exponent=exponent))


def _build(model_name, max_field, store=None, **eng_kwargs):
    spec = ctr_spec(model_name, "criteo", 16, 256, max_field=max_field)
    model = CTR_MODELS[model_name](spec)
    params = model.init(jax.random.PRNGKey(0))
    return spec, InferenceEngine(model, params, store=store, **eng_kwargs)


def _sync(eng, ids, waves):
    t0 = time.perf_counter()
    for wave in np.array_split(ids, waves):
        eng.submit_many(list(wave))
        eng.serve_pending()
    eng.flush()
    return time.perf_counter() - t0


def _async(eng, ids):
    eng.start()
    t0 = time.perf_counter()
    futs = eng.submit_many(list(ids))
    for f in futs:
        f.result(timeout=300.0)
    dt = time.perf_counter() - t0
    eng.stop()
    return dt


def run(quick: bool = False, dry: bool = False) -> dict:
    n = 64 if dry else (400 if quick else 2000)
    ladder = (8, 16) if dry else (32, 64, 128, 256)
    max_field = 2_000 if dry else MAX_FIELD
    models = ["widedeep"] if (dry or quick) else ["deepfm", "dcnv2"]
    schema = CRITEO.scaled(max_field)
    ids = _stream(schema, n)
    results = {}

    # --- sync drain vs async futures intake -------------------------------
    for model_name in models:
        policy = TimeoutBatch(BucketedBatch(ladder), max_wait_ms=1.0)
        _, eng_s = _build(model_name, max_field, policy=policy)
        eng_s.warmup()
        dt_s = _sync(eng_s, ids, waves=4 if dry else 10)
        _, eng_a = _build(model_name, max_field, policy=policy)
        eng_a.warmup()
        dt_a = _async(eng_a, ids)
        ss, sa = eng_s.stats, eng_a.stats
        emit(f"serving_async/{model_name}/sync", dt_s / n * 1e6,
             f"req_s={n/dt_s:.0f} p99_ms={ss.p99_ms:.1f} "
             f"batches={ss.n_batches}")
        emit(f"serving_async/{model_name}/async", dt_a / n * 1e6,
             f"req_s={n/dt_a:.0f} p99_ms={sa.p99_ms:.1f} "
             f"batches={sa.n_batches} worker_drained=True")
        results[f"{model_name}/speedup"] = dt_s / dt_a

    # --- refresh-without-recompile under zipf traffic ----------------------
    store = CachedStore(
        ctr_spec(models[0], "criteo", 16, 256,
                 max_field=max_field).embedding_spec(),
        capacity=max(64, max_field // 50))
    _, eng = _build(models[0], max_field, store=store,
                    policy=BucketedBatch(ladder),
                    refresh_every=2)                 # refresh every 2 batches
    eng.warmup()
    compiles_before = eng.stats.cache_misses
    plans_before = set(eng.cached_plans)
    for wave in np.array_split(ids, 4):
        eng.submit_many(list(wave))
        eng.serve_pending()
    eng.flush()
    st = eng.stats
    survived = (eng.stats.cache_misses == compiles_before
                and set(eng.cached_plans) == plans_before)
    emit(f"serving_async/{models[0]}/refresh_survival",
         st.compute_ms_total / max(st.n_batches, 1) * 1e3,
         f"refreshes={st.emb_cache_refreshes} "
         f"compiles={st.cache_misses} survived={survived} "
         f"emb_hit={st.emb_cache_hit_rate:.2f} "
         f"cached_traffic={st.emb_cached_traffic_fraction:.2f}")
    results["refresh_survived"] = survived

    # --- two-model runtime through one async intake -------------------------
    if not dry:
        rt = ServingRuntime()
        for m in (models if len(models) > 1 else models + ["dcn"]):
            spec = ctr_spec(m, "criteo", 16, 256, max_field=max_field)
            model = CTR_MODELS[m](spec)
            rt.add_model(m, model, model.init(jax.random.PRNGKey(0)),
                         policy=TimeoutBatch(BucketedBatch(ladder),
                                             max_wait_ms=1.0))
        rt.warmup()
        rt.start()
        t0 = time.perf_counter()
        futs = [rt.submit(rt.models[i % len(rt.models)], row)
                for i, row in enumerate(ids)]
        for f in futs:
            f.result(timeout=300.0)
        dt = time.perf_counter() - t0
        rt.stop()
        agg = rt.stats()
        emit("serving_async/runtime/2models", dt / n * 1e6,
             f"req_s={n/dt:.0f} p99_ms={agg.p99_ms:.1f} "
             f"models={agg.n_models} batches={agg.n_batches}")
        results["runtime/req_s"] = n / dt
    return results


if __name__ == "__main__":
    run()
