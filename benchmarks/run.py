"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (scaffold contract).
``--quick`` shrinks sweeps for CI; ``--dry`` shrinks further to a smoke
configuration (every driver must *run*, numbers are throwaway — the CI
bench-smoke job uses it so drivers can't silently rot); default exercises
the paper grids.
"""

from __future__ import annotations

import argparse
import inspect
import json
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--dry", action="store_true",
                    help="tiny smoke config (implies --quick)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark names")
    ap.add_argument("--json", default=None,
                    help="write every driver's run() result dict to this "
                         "JSON file (CI uploads it as the perf-trajectory "
                         "artifact)")
    args = ap.parse_args()

    from . import (accuracy_parity, breakdown, e2e_speedup, embedding_cache,
                   embedding_host, embedding_sensitivity, mlp_quant,
                   roofline_report, scheduling, serving_async,
                   serving_batching, serving_mesh, serving_updates,
                   workload_allocation)
    suites = {
        "accuracy_parity": accuracy_parity,       # Table I
        "e2e_speedup": e2e_speedup,               # Fig. 7 / Table II
        "breakdown": breakdown,                   # Fig. 8
        "embedding_sensitivity": embedding_sensitivity,  # Fig. 10
        "embedding_cache": embedding_cache,       # store tiering sweep
        "embedding_host": embedding_host,         # out-of-HBM host tier
        "mlp_quant": mlp_quant,                   # int8 dense-branch compute
        "workload_allocation": workload_allocation,      # Fig. 11
        "scheduling": scheduling,                 # Fig. 12/13
        "serving_batching": serving_batching,     # Fig. 7 serving policies
        "serving_async": serving_async,           # async runtime + refresh
        "serving_updates": serving_updates,       # online trainer deltas
        "serving_mesh": serving_mesh,             # multi-chip plans+refresh
        "roofline_report": roofline_report,       # §Roofline
    }
    only = set(args.only.split(",")) if args.only else None
    failed = []
    collected: dict[str, dict] = {}
    for name, mod in suites.items():
        if only and name not in only:
            continue
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        kwargs = {"quick": args.quick or args.dry}
        if args.dry and "dry" in inspect.signature(mod.run).parameters:
            kwargs["dry"] = True
        try:
            result = mod.run(**kwargs)
            if isinstance(result, dict):
                collected[name] = result
        except Exception:
            traceback.print_exc()
            failed.append(name)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if args.json:
        with open(args.json, "w") as f:
            # default=str: numpy scalars/bools and PartitionSpecs all
            # stringify rather than breaking the artifact dump
            json.dump({"failed": failed, "results": collected}, f,
                      indent=2, default=str, sort_keys=True)
        print(f"# wrote {args.json}")
    if failed:
        print(f"# FAILED: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
