"""Paper Fig. 8 — component-wise breakdown on Criteo.

  PyTorch-A   naive + per-field host transfer & dtype-conversion overhead
  PyTorch-B   consolidated transfer/conversion, still serial + eager
  DPIFrame-A  + fused multi-table embedding (C2/C3)
  DPIFrame-B  + non-GEMM operator fusion (C5)
  DPIFrame-C  + breadth-first inter-module schedule, whole-graph program (C4)
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ctr_spec
from repro.core import compile_plan
from repro.data.synthetic import CRITEO, synthetic_batch
from repro.models.ctr import CTR_MODELS

from .common import emit, time_fn

BATCH = 2048
MAX_FIELD = 100_000

LEVEL_OF = {"pytorch_b": "naive", "dpiframe_a": "fused_emb",
            "dpiframe_b": "fused_all", "dpiframe_c": "dual"}


def run(quick: bool = False) -> dict:
    schema = CRITEO.scaled(MAX_FIELD)
    batch = synthetic_batch(schema, 0, BATCH)
    ids = batch["ids"]
    # PyTorch-A's extra sin: fields arrive as separate float32 columns and
    # are converted + stacked per inference call
    float_cols = [np.asarray(ids[:, i], dtype=np.float32)
                  for i in range(schema.k)]
    results = {}
    for model_name in (["dcnv2"] if quick else list(CTR_MODELS)):
        spec = ctr_spec(model_name, "criteo", 16, 256, max_field=MAX_FIELD)
        model = CTR_MODELS[model_name](spec)
        params = model.init(jax.random.PRNGKey(0))
        times = {}
        # PyTorch-A: per-field conversion + naive eager execution
        plan_naive = compile_plan(model, params, "naive", BATCH)

        def pytorch_a(cols):
            converted = [jnp.asarray(c).astype(jnp.int32) for c in cols]
            return plan_naive.step(jnp.stack(converted, axis=1))

        times["pytorch_a"] = time_fn(pytorch_a, float_cols, reps=3, warmup=1)
        for tag, level in LEVEL_OF.items():
            plan = compile_plan(model, params, level, BATCH)
            times[tag] = time_fn(plan.step, ids, reps=3, warmup=1)
        base = times["pytorch_a"]
        for tag, t in times.items():
            emit(f"breakdown/{model_name}/{tag}", t,
                 f"speedup_vs_pytorch_a={base/t:.2f}x")
        results[model_name] = times
    return results


if __name__ == "__main__":
    run()
