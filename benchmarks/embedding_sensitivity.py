"""Paper Fig. 10 — embedding sensitivity: batch size, embedding dim,
#fields, #features. Fused single-gather (Alg. 1, "jnp" strategy on CPU =
identical algorithm at the XLA level) vs per-field serial lookup + concat.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import FusedEmbeddingCollection, FusedEmbeddingSpec

from .common import emit, time_fn


def _setup(k: int, n: int, d: int):
    spec = FusedEmbeddingSpec(field_sizes=(n,) * k, dim=d)
    emb = FusedEmbeddingCollection(spec)
    params = emb.init(jax.random.PRNGKey(0))
    return emb, params


def _ids(k: int, n: int, b: int):
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.integers(0, n, size=(b, k)), dtype=jnp.int32)


def _pair(emb, params, ids, tag: str) -> float:
    # params passed as arguments (a closure would bake the mega-table into
    # the executable as multi-GB constants)
    fused = jax.jit(lambda p, i: emb.apply(p, i, strategy="jnp"))
    serial = jax.jit(lambda p, i: emb.apply(p, i, strategy="serial"))
    tf = time_fn(fused, params, ids, reps=3, warmup=1)
    ts = time_fn(serial, params, ids, reps=3, warmup=1)
    emit(f"emb/{tag}/serial", ts)
    emit(f"emb/{tag}/fused", tf, f"speedup={ts/tf:.2f}x")
    return ts / tf


def run(quick: bool = False) -> dict:
    out = {}
    # (1) batch size sweep (paper: criteo, d=32)
    for b in ([2048] if quick else [1024, 4096, 16384, 65536]):
        emb, params = _setup(39, 100_000, 32)
        out[f"batch_{b}"] = _pair(emb, params, _ids(39, 100_000, b),
                                  f"batch_{b}")
    # (2) embedding dim sweep (batch 2048)
    for d in ([16] if quick else [8, 16, 32, 64]):
        emb, params = _setup(39, 100_000, d)
        out[f"dim_{d}"] = _pair(emb, params, _ids(39, 100_000, 2048),
                                f"dim_{d}")
    # (3) #fields sweep (500k features per field in the paper; 100k here)
    for k in ([20] if quick else [10, 20, 40, 80]):
        emb, params = _setup(k, 100_000, 32)
        out[f"fields_{k}"] = _pair(emb, params, _ids(k, 100_000, 2048),
                                   f"fields_{k}")
    # (4) #features sweep (height of tables; paper: no effect)
    for n in ([10_000] if quick else [1_000, 10_000, 100_000, 300_000]):
        emb, params = _setup(50, n, 32)
        out[f"features_{n}"] = _pair(emb, params, _ids(50, n, 2048),
                                     f"features_{n}")
    return out


if __name__ == "__main__":
    run()
