"""Render the dry-run roofline records (experiments/dryrun/*.json) as the
EXPERIMENTS.md §Roofline markdown table."""

from __future__ import annotations

import glob
import json
import os

from .common import emit

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(out_dir: str = "experiments/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*__*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(recs: list[dict], mesh: str = "pod") -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "dominant | useful ratio | roofline frac | fits 16G |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"],
                                         ORDER.index(r["shape"])
                                         if r["shape"] in ORDER else 9)):
        if r.get("status") != "ok" or r.get("mesh") != mesh:
            continue
        rl = r["roofline"]
        tmax = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        frac = rl["compute_s"] / tmax if tmax else 0.0
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.4g} | "
            f"{rl['memory_s']:.4g} | {rl['collective_s']:.4g} | "
            f"{rl['dominant']} | {rl['useful_ratio']:.2f} | {frac:.2f} | "
            f"{'Y' if rl['fits_hbm'] else 'N'} |")
    return "\n".join(rows)


def run(quick: bool = False) -> dict:
    recs = load()
    ok = [r for r in recs if r.get("status") == "ok"]
    for r in ok:
        rl = r["roofline"]
        tmax = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
             tmax * 1e6,
             f"dominant={rl['dominant']} "
             f"frac={rl['compute_s']/tmax if tmax else 0:.2f}")
    if ok:
        print(table(recs))
    else:
        emit("roofline/no_records", 0.0,
             "run: python -m repro.launch.dryrun --all --mesh pod "
             "--out experiments/dryrun")
    return {"n_records": len(ok)}


if __name__ == "__main__":
    run()
