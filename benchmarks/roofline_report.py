"""Render the dry-run roofline records (experiments/dryrun/*.json) as the
EXPERIMENTS.md §Roofline markdown table."""

from __future__ import annotations

import glob
import json
import os

from .common import emit

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(out_dir: str = "experiments/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*__*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(recs: list[dict], mesh: str = "pod") -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "dominant | useful ratio | roofline frac | AI f32 | AI int8 | "
            "fits 16G |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"],
                                         ORDER.index(r["shape"])
                                         if r["shape"] in ORDER else 9)):
        if r.get("status") != "ok" or r.get("mesh") != mesh:
            continue
        rl = r["roofline"]
        tmax = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        frac = rl["compute_s"] / tmax if tmax else 0.0
        # int8 companion columns default to 0 for records written before
        # the quantized-compute roofline landed
        ai = rl.get("arith_intensity", 0.0)
        ai8 = rl.get("arith_intensity_int8", 0.0)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.4g} | "
            f"{rl['memory_s']:.4g} | {rl['collective_s']:.4g} | "
            f"{rl['dominant']} | {rl['useful_ratio']:.2f} | {frac:.2f} | "
            f"{ai:.1f} | {ai8:.1f} | "
            f"{'Y' if rl['fits_hbm'] else 'N'} |")
    return "\n".join(rows)


def run(quick: bool = False) -> dict:
    recs = load()
    ok = [r for r in recs if r.get("status") == "ok"]
    for r in ok:
        rl = r["roofline"]
        tmax = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
             tmax * 1e6,
             f"dominant={rl['dominant']} "
             f"frac={rl['compute_s']/tmax if tmax else 0:.2f}")
        # int8 twin bound: quantized matmuls at the doubled MXU peak plus
        # the shrunken weights-read HBM term (.get(): pre-quantization
        # dry-run records carry no int8 fields — emit 0-valued lines
        # rather than fail so stale artifacts stay renderable)
        c8 = rl.get("compute_s_int8", 0.0)
        m8 = rl.get("memory_s_int8", 0.0)
        tmax8 = max(c8, m8, rl["collective_s"]) if (c8 or m8) else 0.0
        emit(f"roofline_int8/{r['arch']}/{r['shape']}/{r['mesh']}",
             tmax8 * 1e6,
             f"ai={rl.get('arith_intensity', 0.0):.1f} "
             f"ai_int8={rl.get('arith_intensity_int8', 0.0):.1f}")
    if ok:
        print(table(recs))
    else:
        # NaN placeholder, not a 0.0 metric: a zero roofline bound reads
        # as "free step" to anything diffing the emitted numbers — the
        # skipped flag lets callers (and diff_baseline) tell "suite ran
        # with no dry-run artifacts" from "suite measured zero"
        emit("roofline/no_records", float("nan"),
             "skipped=1 run: python -m repro.launch.dryrun --all "
             "--mesh pod --out experiments/dryrun")
        return {"n_records": 0, "skipped": True}
    return {"n_records": len(ok)}


if __name__ == "__main__":
    run()
