"""Online model-updates benchmark — trainer delta streams applied under
live batch traffic.

Each cell serves a zipf request stream through a ``ServingRuntime`` while
a ``SyntheticTrainer`` delta stream drains on the runtime's
``delta_every`` cadence (background pulls off the intake hot path), then
hard-asserts the online-update contract where the sweep runs (CI dry
included):

  * **zero recompiles** — the plan-cache compile count and plan set are
    identical before and after the whole stream applied (deltas publish
    through the versioned double-buffered swap, never through XLA);
  * **version accounting** — ``emb_version`` ends exactly at the number
    of pushed batches (every push bumps once, nothing else does);
  * **value correctness** — post-stream scores are bit-exact with a
    dense engine rebuilt from a table with the same deltas applied
    (fp32 cells), or with a fresh int8 tier built from that delta-applied
    table (int8 cells — the re-quantization parity contract: pushing
    fp32 rows through ``push_update`` lands on the same int8 grid as
    loading them cold);
  * **staleness drained** — ``rows_behind`` reads 0 once the stream is
    consumed.

CPU timings are noise-bound; each cell's ``structural`` sub-dict holds
only traffic-deterministic values (push/row/version counts and the
assertion outcomes above) and is pinned by ``BENCH_serving.json`` via
``benchmarks/diff_baseline.py``. Timing fields live in ``timing`` and
are ignored by the diff.
"""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ctr_spec
from repro.data.synthetic import CRITEO, zipf_ids
from repro.embedding import CachedStore, HostBackedStore
from repro.models.ctr import CTR_MODELS
from repro.serving import (BucketedBatch, InferenceEngine, ServingRuntime,
                           SyntheticTrainer, TimeoutBatch)

from .common import emit

MAX_FIELD = 100_000


def _stream(schema, n, seed=1):
    return np.asarray(zipf_ids(jax.random.PRNGKey(seed), n,
                               schema.field_sizes, exponent=1.1))


def _make_store(kind, espec, capacity, row_dtype):
    cls = {"cached": CachedStore, "host": HostBackedStore}[kind]
    return cls(espec, capacity=capacity, row_dtype=row_dtype)


def _cell(store_kind: str, row_dtype: str | None, n_requests: int,
          delta_every: int, delta_rows: int, n_pushes: int,
          ladder, max_field: int) -> dict:
    """One (store tier × delta stream × traffic) cell. Small dims keep
    the compile cost bounded; the update path under test is
    width-independent."""
    schema = CRITEO.scaled(max_field)
    spec = ctr_spec("widedeep", "criteo", 8, 64, max_field=max_field)
    espec = spec.embedding_spec()
    # separate instances: an engine binds its store to the model's
    # collection, so serving and reference must not share one model
    model = CTR_MODELS["widedeep"](spec)
    ref_model = CTR_MODELS["widedeep"](spec)
    ids = _stream(schema, n_requests)
    probe = ids[:ladder[-1]]
    trainer = SyntheticTrainer(espec, rows_per_batch=delta_rows,
                               n_batches=n_pushes, seed=0)

    rt = ServingRuntime(delta_every=delta_every)
    rt.add_model("m", model, model.init(jax.random.PRNGKey(0)),
                 policy=TimeoutBatch(BucketedBatch(ladder), max_wait_ms=2.0),
                 store=_make_store(store_kind, espec,
                                   capacity=max(64, max_field // 50),
                                   row_dtype=row_dtype),
                 worker_tick_ms=1.0)
    rt.attach_delta_stream("m", trainer)
    rt.warmup()
    eng = rt.engine("m")
    eng.predict(probe)                        # pin the probe plan too
    compiles_before = eng.stats.cache_misses
    plans_before = set(eng.cached_plans)

    rt.start()
    t0 = time.perf_counter()
    futs = [rt.submit("m", row) for row in ids]
    for f in futs:
        f.result(timeout=600.0)
    dt = time.perf_counter() - t0
    rt.stop()                                 # joins the background pull
    rt.pull_updates()                         # leftovers, deterministically
    st = rt.stats()

    # --- the contract, hard-asserted ---------------------------------------
    assert eng.stats.cache_misses == compiles_before \
        and set(eng.cached_plans) == plans_before, (
            f"online deltas recompiled: {compiles_before} -> "
            f"{eng.stats.cache_misses} compiles")
    assert st.emb_version == n_pushes, (
        f"version drift: {n_pushes} pushes but emb_version={st.emb_version}")
    assert st.rows_behind == 0, f"stream not drained: {st.rows_behind} rows"

    # reference: the same delta stream applied to a dense fp32 table
    # (numpy fancy assignment keeps the last duplicate — the store's
    # dedupe rule), then served through a cold engine of the same tier
    ref_params = ref_model.init(jax.random.PRNGKey(0))
    key = ref_model.main_embedding_key
    table = np.array(ref_params[key]["mega_table"])
    replay = trainer.replay()
    while (batch := replay.next_batch()) is not None:
        b_ids, b_rows = batch
        table[b_ids] = b_rows
    ref_params = dict(ref_params)
    ref_params[key] = {**ref_params[key], "mega_table": jnp.asarray(table)}
    ref_store = (None if row_dtype is None else
                 _make_store(store_kind, espec,
                             capacity=max(64, max_field // 50),
                             row_dtype=row_dtype))
    ref_eng = InferenceEngine(ref_model, ref_params,
                              policy=BucketedBatch(ladder), store=ref_store)
    exact = bool(np.array_equal(eng.predict(probe), ref_eng.predict(probe)))
    assert exact, "post-stream scores diverge from the rebuilt reference"

    dtype_tag = row_dtype or "fp32"
    tag = f"{store_kind}_{dtype_tag}_r{delta_rows}"
    emit(f"serving_updates/{tag}", dt / n_requests * 1e6,
         f"req_s={n_requests/dt:.0f} pushes={st.emb_delta_pushes} "
         f"delta_rows={st.emb_delta_rows} version=v{st.emb_version} "
         f"delta_rows_s={st.emb_delta_rows/dt:.0f} exact={exact}")
    return {
        "structural": {
            # deterministic for fixed traffic + trainer seed: pinned by
            # BENCH_serving.json
            "store": store_kind,
            "row_dtype": dtype_tag,
            "n_requests": n_requests,
            "delta_every": delta_every,
            "n_pushes": int(st.emb_delta_pushes),
            "delta_rows_applied": int(st.emb_delta_rows),
            "emb_version": int(st.emb_version),
            "zero_recompiles": True,          # asserted above
            "bitexact_after_deltas": exact,   # requant parity on int8 cells
            "staleness_drained": True,        # asserted above
        },
        "timing": {
            "req_s": n_requests / dt,
            "delta_rows_per_s": st.emb_delta_rows / dt,
            "p99_ms": st.p99_ms,
        },
    }


def run(quick: bool = False, dry: bool = False) -> dict:
    n = 64 if dry else (256 if quick else 2000)
    ladder = (8, 16) if (dry or quick) else (32, 64, 128, 256)
    max_field = 2_000 if (dry or quick) else MAX_FIELD
    # cell names are part of the pinned baseline: the CI dry run must
    # produce exactly the dry list below (diff_baseline compares cell sets)
    if dry or quick:
        cells = [("cached", None, 32, 2), ("cached", "int8", 32, 2),
                 ("host", None, 32, 2)]
    else:
        cells = [("cached", None, 256, 8), ("cached", "int8", 256, 8),
                 ("host", None, 256, 8), ("host", "int8", 256, 8)]
    results = {}
    for store_kind, row_dtype, delta_rows, n_pushes in cells:
        tag = f"{store_kind}_{row_dtype or 'fp32'}_r{delta_rows}"
        results[tag] = _cell(store_kind, row_dtype, n, delta_every=n // 4,
                             delta_rows=delta_rows, n_pushes=n_pushes,
                             ladder=ladder, max_field=max_field)
    return results


if __name__ == "__main__":
    run()
