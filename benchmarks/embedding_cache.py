"""Embedding-store cache sweep: capacity × traffic skew.

The DenseStore/CachedStore question in numbers (HugeCTR inference
parameter server, arXiv:2210.08804): at a fixed hot-row budget C, how much
of the traffic does the cache absorb as the zipf exponent grows, and what
does the two-level gather cost relative to the monolithic mega-table?

Per (capacity, skew) cell:
  1. warm the store's admission counters with observed skewed traffic,
  2. ``refresh`` (admit the top-C rows),
  3. measure the *post-refresh* hit rate on fresh traffic from the same
     distribution, the cached-traffic fraction, and the fused one-hot
     lookup time through both stores.

CSV: ``emb_cache/C{cap}/{skew}/{dense|cached}``; the cached line's
``derived`` column carries ``hit_rate=…,cached_traffic=…``. Both counters
must increase with skew at fixed capacity — uniform traffic pins the hit
rate near C/rows, zipf concentrates it toward 1.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.embedding import (CachedStore, FusedEmbeddingCollection,
                             FusedEmbeddingSpec)
from repro.data.synthetic import zipf_ids

from .common import emit, time_fn


def _traffic(key, n_batches: int, batch: int, field_sizes, exponent: float):
    return [np.asarray(zipf_ids(jax.random.fold_in(key, t), batch,
                                field_sizes, exponent=exponent))
            for t in range(n_batches)]


def _cell(spec: FusedEmbeddingSpec, capacity: int, exponent: float,
          batch: int, warm_batches: int, tag: str,
          row_dtype: str | None = None) -> dict:
    key = jax.random.PRNGKey(0)
    dense = FusedEmbeddingCollection(spec)
    params_d = dense.init(key)
    store = CachedStore(spec, capacity=capacity, row_dtype=row_dtype)
    cached = FusedEmbeddingCollection(spec, store=store)
    params_c = store.from_dense(params_d)        # same table, tiered layout

    # 1-2. observe warmup traffic, admit the top-C rows
    for ids in _traffic(key, warm_batches, batch, spec.field_sizes, exponent):
        cached.observe(ids)
    params_c = store.refresh(params_c)

    # 3. post-refresh hit rate on fresh traffic (same distribution)
    hits0, lookups0 = store.stats.hits, store.stats.lookups
    fresh = _traffic(jax.random.fold_in(key, 10_000), warm_batches, batch,
                     spec.field_sizes, exponent)
    for ids in fresh:
        cached.observe(ids)
    dlook = store.stats.lookups - lookups0
    hit_rate = (store.stats.hits - hits0) / dlook if dlook else 0.0

    ids = jnp.asarray(fresh[0], dtype=jnp.int32)
    # params passed as arguments (a closure would bake the tables into the
    # executable as multi-GB constants)
    f_dense = jax.jit(lambda p, i: dense.apply(p, i))
    f_cached = jax.jit(lambda p, i: cached.apply(p, i))
    if row_dtype is not None:
        # lossy int8 rows: tolerance gate instead of the fp32 bit-exactness
        np.testing.assert_allclose(np.asarray(f_cached(params_c, ids)),
                                   np.asarray(f_dense(params_d, ids)),
                                   rtol=0, atol=1e-2)
    td = time_fn(f_dense, params_d, ids, reps=3, warmup=1)
    tc = time_fn(f_cached, params_c, ids, reps=3, warmup=1)
    ctf = store.cached_traffic_fraction
    emit(f"emb_cache/{tag}/dense", td)
    emit(f"emb_cache/{tag}/cached", tc,
         f"hit_rate={hit_rate:.3f},cached_traffic={ctf:.3f},"
         f"refreshes={store.stats.refreshes},"
         f"gather={store.stats.gather_bytes}B")
    return {"hit_rate": hit_rate, "cached_traffic": ctf,
            "dense_us": td, "cached_us": tc,
            "row_dtype": row_dtype or "fp32",
            "wire_row_bytes": int(store.wire_row_bytes),
            "gather_bytes": int(store.stats.gather_bytes)}


def run(quick: bool = False, dry: bool = False) -> dict:
    if dry:
        k, n, d, batch, warm = 4, 2_000, 8, 256, 2
        capacities, exponents = [64], [0.0, 1.3]
    elif quick:
        k, n, d, batch, warm = 8, 20_000, 16, 1024, 4
        capacities, exponents = [1_024], [0.0, 1.05, 1.3]
    else:
        k, n, d, batch, warm = 26, 100_000, 32, 4096, 8
        capacities = [4_096, 32_768, 262_144]
        exponents = [0.0, 1.05, 1.2, 1.4, 1.6]
    spec = FusedEmbeddingSpec(field_sizes=(n,) * k, dim=d)
    out = {}
    for cap in capacities:
        for e in exponents:
            skew = "uniform" if e == 0.0 else f"zipf{e}"
            out[f"C{cap}_{skew}"] = _cell(spec, cap, e, batch, warm,
                                          f"C{cap}/{skew}")

    # fp32-vs-int8 twin at d=32: same traffic through the same capacity,
    # wire bytes 128 vs 36 per row — the cached tier's bytes-moved column
    spec32 = FusedEmbeddingSpec(field_sizes=(n,) * k, dim=32)
    cap, e = capacities[0], exponents[-1]
    twin = {}
    for rd in (None, "int8"):
        mode = rd or "fp32"
        twin[mode] = _cell(spec32, cap, e, batch, warm,
                           f"C{cap}/zipf{e}/d32/{mode}", row_dtype=rd)
        out[f"q8_twin_d32_{mode}"] = twin[mode]
    ratio = twin["fp32"]["gather_bytes"] / twin["int8"]["gather_bytes"]
    assert ratio >= 3.5, twin
    out["q8_twin_d32"] = {"gather_ratio": round(ratio, 6)}
    return out


if __name__ == "__main__":
    run()
