"""Multi-chip serving benchmark — 1-device vs simulated-mesh plans, and
mesh-aware refresh plan-survival.

Three measurements on the same zipf request stream:

  1. **1dev**: the dense single-device engine (no mesh) — the throughput
     and numerics baseline.
  2. **mesh**: the same model served through ``compile_plan(mesh=...)``
     on a data-only mesh and on a data×model mesh — batch inputs sharded
     over the data axis, embedding tables vocab-parallel over the model
     axis. On a host-simulated CPU mesh the *throughput* numbers mostly
     show partitioning overhead (every "chip" is a thread of one CPU);
     the structural properties are the point and are hard-asserted when
     >1 device is available:
       - the plan's ``input_shardings["ids"]`` puts the batch dim on
         ``data``;
       - the engine's published ``backing`` table is row-sharded over
         ``model`` (cache + ``slot_of_row`` replicated);
       - mesh scores match the 1-device baseline (tight tolerance — XLA
         partitioning may differ by float ulps).
  3. **refresh survival**: a ``CachedStore`` engine on the data×model
     mesh refreshes under zipf traffic; the post-refresh serve must be
     **bit-exact** with the pre-refresh serve and the plan cache must
     report zero new compiles (the published tensors were placed to the
     plans' shardings — a true multi-chip refresh, HugeCTR-style).

Run directly (simulates 8 host devices unless XLA_FLAGS already forces a
count) or via ``benchmarks.run``; the CI ``tier1-mesh`` job runs
``--dry`` under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""

from __future__ import annotations

import os

if __name__ == "__main__":  # direct runs: simulate chips before jax loads
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8").strip()

import time

import numpy as np
import jax

from repro.compat import make_mesh
from repro.configs import ctr_spec
from repro.data.synthetic import CRITEO, zipf_ids
from repro.embedding import CachedStore
from repro.models.ctr import CTR_MODELS
from repro.serving import BucketedBatch, InferenceEngine

from .common import emit

MAX_FIELD = 100_000


def _build(model_name, max_field, ladder, mesh=None, cache_capacity=None,
           **eng_kwargs):
    spec = ctr_spec(model_name, "criteo", 16, 256, max_field=max_field)
    model = CTR_MODELS[model_name](spec)
    params = model.init(jax.random.PRNGKey(0))
    store = (CachedStore(spec.embedding_spec(), capacity=cache_capacity)
             if cache_capacity else None)
    return InferenceEngine(model, params, mesh=mesh, store=store,
                           policy=BucketedBatch(ladder), **eng_kwargs)


def _serve(eng, ids, waves):
    """Sync wave drain; returns (seconds, scores in submit order)."""
    out = []
    t0 = time.perf_counter()
    for wave in np.array_split(ids, waves):
        eng.submit_many(list(wave))
        out.append(eng.serve_pending())
    out.append(eng.flush())
    return time.perf_counter() - t0, np.concatenate(out)


def run(quick: bool = False, dry: bool = False) -> dict:
    dc = jax.device_count()
    n = 96 if dry else (400 if quick else 2000)
    ladder = (8, 16) if dry else (32, 64, 128, 256)
    max_field = 2_000 if dry else MAX_FIELD
    model_name = "widedeep" if (dry or quick) else "dcnv2"
    waves = 4 if dry else 10
    schema = CRITEO.scaled(max_field)
    ids = np.asarray(zipf_ids(jax.random.PRNGKey(0), n,
                              schema.field_sizes, exponent=1.1))
    results = {"devices": dc}

    # --- 1-device baseline -------------------------------------------------
    eng1 = _build(model_name, max_field, ladder)
    eng1.warmup()
    dt1, want = _serve(eng1, ids, waves)
    emit(f"serving_mesh/{model_name}/1dev", dt1 / n * 1e6,
         f"req_s={n/dt1:.0f} p99_ms={eng1.stats.p99_ms:.1f} "
         f"batches={eng1.stats.n_batches}")
    results["1dev/req_s"] = n / dt1

    # --- mesh shapes to exercise ------------------------------------------
    if dc >= 8:
        shapes = [((8,), ("data",)), ((4, 2), ("data", "model"))]
    elif dc >= 2:
        shapes = [((dc,), ("data",)), ((dc // 2 or 1, 2), ("data", "model"))]
    else:
        print("# serving_mesh: 1 device — run under XLA_FLAGS="
              "--xla_force_host_platform_device_count=8 for the real "
              "multi-chip sweep; exercising a 1x1 mesh only")
        shapes = [((1, 1), ("data", "model"))]

    for sizes, axes in shapes:
        mesh = make_mesh(sizes, axes)
        tag = "x".join(f"{a}{s}" for a, s in zip(axes, sizes))
        eng = _build(model_name, max_field, ladder, mesh=mesh)
        eng.warmup()
        dt, got = _serve(eng, ids, waves)
        emit(f"serving_mesh/{model_name}/mesh_{tag}", dt / n * 1e6,
             f"req_s={n/dt:.0f} p99_ms={eng.stats.p99_ms:.1f} "
             f"batches={eng.stats.n_batches}")
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6,
                                   err_msg=f"mesh {tag} vs 1dev")
        # structural contract: batch inputs sharded over the data axis
        # (every ladder bucket divides the data axis in this config)
        plan = eng.plan_for(ladder[-1])
        ids_spec = plan.input_shardings["ids"].spec
        if dc >= 2:
            assert ids_spec[0] == "data", ids_spec
        results[f"mesh_{tag}/req_s"] = n / dt
        results[f"mesh_{tag}/ids_spec"] = str(ids_spec)

    # --- mesh-aware refresh survival --------------------------------------
    sizes, axes = shapes[-1]
    mesh = make_mesh(sizes, axes)
    tag = "x".join(f"{a}{s}" for a, s in zip(axes, sizes))
    eng = _build(model_name, max_field, ladder, mesh=mesh,
                 cache_capacity=max(64, max_field // 50),
                 refresh_every=2)              # refresh every 2 batches
    eng.warmup()
    compiles_before = eng.stats.cache_misses
    plans_before = set(eng.cached_plans)
    _, pre = _serve(eng, ids, waves)           # refreshes fire mid-stream
    eng.refresh_cache()
    _, post = _serve(eng, ids, waves)
    st = eng.stats
    survived = (st.cache_misses == compiles_before
                and set(eng.cached_plans) == plans_before)
    bit_exact = bool(np.array_equal(pre, post))
    sub = eng.params[eng.model.main_embedding_key]
    backing_spec = sub["backing"].sharding.spec
    cache_spec = sub["cache"].sharding.spec
    emit(f"serving_mesh/{model_name}/refresh_{tag}",
         st.compute_ms_total / max(st.n_batches, 1) * 1e3,
         f"refreshes={st.emb_cache_refreshes} compiles={st.cache_misses} "
         f"survived={survived} bit_exact={bit_exact} "
         f"backing={backing_spec} cache={cache_spec}")
    assert survived, "refresh recompiled or dropped plans on the mesh"
    assert bit_exact, "post-refresh serve is not bit-exact"
    np.testing.assert_allclose(post, want, rtol=1e-5, atol=1e-6,
                               err_msg="post-refresh mesh vs 1dev")
    if "model" in axes and dict(zip(axes, sizes)).get("model", 1) > 1:
        # published (post-refresh) backing must still be row-sharded
        assert tuple(backing_spec)[0] == "model", backing_spec
        assert all(a is None for a in tuple(cache_spec)), cache_spec
    results["refresh/survived"] = survived
    results["refresh/bit_exact"] = bit_exact
    results["refresh/backing_spec"] = str(backing_spec)
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--dry", action="store_true")
    args = ap.parse_args()
    print(run(quick=args.quick, dry=args.dry))
